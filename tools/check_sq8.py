"""CI fast-lane int8 smoke (DESIGN.md §16): the sq8 Pallas kernel forms
must match the pure-jnp oracles in ``repro/kernels/ref.py`` on a 512-row
corpus, for all three metrics, in interpret mode.

Parity bars mirror tests/test_kernels.py: the pairwise form is bit-exact
against its oracle (both lower to the same gemm grouping on CPU); the
gather form is fp32-accumulation-tolerance (the (bk, d) gemm tile and the
oracle's batched dot_general accumulate in different orders).  The
quantizer itself is checked bit-exact against the closed-form NumPy
definition so a drifted scale can't hide inside a loose kernel bound.

  PYTHONPATH=src python tools/check_sq8.py

Exits non-zero on any mismatch.  Forces REPRO_PALLAS_INTERPRET=1 itself
so running it locally exercises the same path CI does.
"""
from __future__ import annotations

import os
import sys

os.environ["REPRO_PALLAS_INTERPRET"] = "1"

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

N, NQ, D = 512, 16, 96


def main() -> int:
    from repro.core import metric as metric_lib
    from repro.kernels import ops, ref

    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(N, D)), jnp.float32)
    q = jnp.asarray(r.normal(size=(NQ, D)), jnp.float32)
    for name in ("l2", "ip", "cosine"):
        met = metric_lib.resolve(name)
        quant = met.prepare_quantized(x)

        # quantizer vs closed form: symmetric per-dim, zero dims -> scale 1
        xp = np.asarray(met.prepare(x))
        scale = np.abs(xp).max(axis=0) / 127.0
        scale = np.where(scale == 0.0, 1.0, scale).astype(np.float32)
        codes = np.clip(np.rint(xp / scale), -127, 127).astype(np.int8)
        np.testing.assert_array_equal(np.asarray(quant.codes), codes,
                                      err_msg=f"{name}: codes drifted")
        np.testing.assert_array_equal(np.asarray(quant.scale), scale,
                                      err_msg=f"{name}: scale drifted")

        # pairwise sq8 kernel (interpret) vs ref oracle: bit-exact
        qp = met.prepare(q)
        got = np.asarray(ops.pairwise_distance_q(q, quant, metric=met))
        want = np.asarray(ref.pairwise_distance_sq8_ref(
            qp, quant.codes, quant.scale, quant.norms, met.kernel))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{name}: pairwise sq8")

        # gather sq8 kernel (interpret) vs ref oracle: fp32 tolerance,
        # cached entries bit-exact passthrough
        k = 24
        ids = r.integers(0, N, size=(NQ, k))
        gcodes = quant.codes[jnp.asarray(ids)]
        gnorms = quant.norms[jnp.asarray(ids)]
        cached = jnp.asarray(r.normal(size=(NQ, k)), jnp.float32)
        mask = jnp.asarray(r.random(size=(NQ, k)) < 0.7)  # True = compute
        got = np.asarray(ops.gather_distance_q(
            q, gcodes, quant.scale, gnorms, cached, mask, metric=met))
        want = np.asarray(ref.gather_distance_sq8_ref(
            qp, gcodes, quant.scale, gnorms, cached, mask, met.kernel))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4,
                                   err_msg=f"{name}: gather sq8")
        np.testing.assert_array_equal(got[~np.asarray(mask)],
                                      want[~np.asarray(mask)],
                                      err_msg=f"{name}: cached passthrough")
        print(f"sq8 smoke ok: metric={name} n={N} d={D} "
              f"(pairwise bit-exact, gather tol, quantizer closed-form)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
