#!/usr/bin/env python
"""Repo-hygiene check: no compiled-Python artifacts may be tracked.

``__pycache__`` directories and ``.pyc``/``.pyo`` bytecode are
machine-local build products; once committed they churn on every Python
upgrade and silently bloat diffs.  The .gitignore already excludes them,
but an ignore rule cannot evict a file that was force-added or tracked
before the rule existed — this check closes that gap by failing CI (and
tests/test_repo.py) whenever ``git ls-files`` reports one.

Exits 1 listing every offending tracked path.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BYTECODE_SUFFIXES = (".pyc", ".pyo")


def is_artifact(path: str) -> bool:
    """True when a repo-relative path is a compiled-Python artifact."""
    return ("__pycache__" in path.split("/")
            or path.endswith(BYTECODE_SUFFIXES))


def tracked_artifacts(root: pathlib.Path = ROOT) -> list[str]:
    out = subprocess.run(["git", "ls-files"], cwd=root, check=True,
                         capture_output=True, text=True).stdout
    return [p for p in out.splitlines() if is_artifact(p)]


def main() -> int:
    bad = tracked_artifacts()
    for path in bad:
        print(f"tracked compiled-Python artifact: {path}", file=sys.stderr)
    if bad:
        print(f"{len(bad)} tracked __pycache__/.pyc file(s) — "
              f"git rm --cached them", file=sys.stderr)
        return 1
    print("repo hygiene OK (no tracked __pycache__/.pyc)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
