#!/usr/bin/env python
"""Repo-hygiene check: no build or runtime artifacts may be tracked.

Two artifact families are rejected:

- ``__pycache__`` directories and ``.pyc``/``.pyo`` bytecode —
  machine-local build products; once committed they churn on every
  Python upgrade and silently bloat diffs.
- ``*.snapshot.npz`` / ``*.snapshot.json`` index snapshots
  (serve/resilience.py) — runtime serving state, potentially hundreds of
  MB of corpus arrays; a tracked snapshot is always a mistake (a test or
  bench wrote one into the tree instead of a tmp dir).
- ``*.wal`` / ``*.stream.npz`` / ``*.stream.json`` streaming-index state
  (serve/streaming.py) — the write-ahead log, external-id sidecar, and
  generation pointer of a mutable serving index; same runtime-state
  argument, with the extra hazard that a tracked WAL replays stale
  mutations into whoever loads it.

The .gitignore already excludes both, but an ignore rule cannot evict a
file that was force-added or tracked before the rule existed — this
check closes that gap by failing CI (and tests/test_repo.py) whenever
``git ls-files`` reports one.

Exits 1 listing every offending tracked path.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BYTECODE_SUFFIXES = (".pyc", ".pyo")
# Keep in sync with serve/resilience.py SNAPSHOT_NPZ / SNAPSHOT_MANIFEST
# (not imported: this tool must run without PYTHONPATH or jax installed).
SNAPSHOT_SUFFIXES = (".snapshot.npz", ".snapshot.json")
# Keep in sync with serve/streaming.py STREAM_SUFFIXES (same rule; the
# sync is pinned by tests/test_repo.py).
STREAM_SUFFIXES = (".wal", ".stream.npz", ".stream.json")


def is_artifact(path: str) -> bool:
    """True when a repo-relative path is a build/runtime artifact."""
    return ("__pycache__" in path.split("/")
            or path.endswith(BYTECODE_SUFFIXES)
            or path.endswith(SNAPSHOT_SUFFIXES)
            or path.endswith(STREAM_SUFFIXES))


def tracked_artifacts(root: pathlib.Path = ROOT) -> list[str]:
    out = subprocess.run(["git", "ls-files"], cwd=root, check=True,
                         capture_output=True, text=True).stdout
    return [p for p in out.splitlines() if is_artifact(p)]


def main() -> int:
    bad = tracked_artifacts()
    for path in bad:
        print(f"tracked artifact: {path}", file=sys.stderr)
    if bad:
        print(f"{len(bad)} tracked artifact file(s) "
              f"(__pycache__/.pyc, *.snapshot.*, or WAL/stream state) — "
              f"git rm --cached them", file=sys.stderr)
        return 1
    print("repo hygiene OK (no tracked __pycache__/.pyc/*.snapshot.*/"
          "*.wal/*.stream.*)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
