#!/usr/bin/env python
"""Docs-consistency check: DESIGN.md section references must resolve.

Module docstrings cite the design record by section number ("DESIGN.md §9",
"DESIGN.md §3, §Perf iteration 5", "§2.1 bit-identity"). Sections get
renumbered; stale references rot silently.  This script scans every .py
file under src/ and tests/ for § tokens that follow a ``DESIGN.md`` mention
(same sentence — references may wrap across comment lines) and verifies
each resolves in DESIGN.md:

  §N / §Name   -> a ``## §N ...`` header exists
  §N.M         -> the §N section body contains a numbered item ``M.``

Exits 1 listing every broken reference.  Run by CI (.github/workflows/
ci.yml) and tests/test_docs.py.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests")
TOKEN = re.compile(r"§([0-9]+(?:\.[0-9]+)?|[A-Za-z][A-Za-z0-9_-]*)")
# a chained reference ("§3, §Perf") allows a short gap before each token
CHAIN = re.compile(r".{0,16}?§([0-9]+(?:\.[0-9]+)?|[A-Za-z][A-Za-z0-9_-]*)",
                   re.S)


def design_sections(text: str) -> dict[str, str]:
    """Map section token -> body text for every ``## §...`` header."""
    secs: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = re.match(r"^##\s+§(\S+)", line)
        if m:
            cur = m.group(1)
            secs[cur] = []
        elif cur is not None:
            secs[cur].append(line)
    return {k: "\n".join(v) for k, v in secs.items()}


def resolves(tok: str, secs: dict[str, str]) -> bool:
    if tok in secs:
        return True
    if "." in tok:
        sec, item = tok.split(".", 1)
        body = secs.get(sec)
        return (body is not None and
                re.search(rf"^\s*{re.escape(item)}\.\s", body, re.M)
                is not None)
    return False


def file_references(text: str) -> list[str]:
    """All § tokens chained after a DESIGN.md mention (unwrapping comment
    and docstring line breaks)."""
    flat = re.sub(r"\s*\n\s*#?\s*", " ", text)
    toks: list[str] = []
    for m in re.finditer(r"DESIGN\.md", flat):
        pos = m.end()
        while True:
            mm = CHAIN.match(flat, pos)
            if not mm:
                break
            toks.append(mm.group(1))
            pos = mm.end()
    return toks


def broken_references(root: pathlib.Path = ROOT) -> list[str]:
    secs = design_sections((root / "DESIGN.md").read_text())
    bad = []
    for top in SCAN_DIRS:
        for path in sorted((root / top).rglob("*.py")):
            for tok in file_references(path.read_text()):
                if not resolves(tok, secs):
                    bad.append(f"{path.relative_to(root)}: DESIGN.md "
                               f"§{tok} does not resolve to a section")
    return bad


def main() -> int:
    bad = broken_references()
    for line in bad:
        print(line, file=sys.stderr)
    if bad:
        print(f"{len(bad)} broken DESIGN.md reference(s)", file=sys.stderr)
        return 1
    print("DESIGN.md references OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
