"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and saves
full JSON artifacts under results/bench/.  The kernel bench additionally
writes the machine-readable serving-search trajectory (QPS, hops, #dist,
peak search-state bytes per config) to ``BENCH_search.json`` at the repo
root — that file is committed, so serving-perf regressions show up as
review diffs instead of living in commit messages.  ``--quick`` runs the
cheap benches only; ``--only <prefix>`` filters.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table4]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

BENCHES = [
    ("kernel", "benchmarks.kernel_microbench", {}),
    ("build", "benchmarks.build_bench", {}),
    ("fig4", "benchmarks.fig4_build_breakdown", {}),
    ("fig5", "benchmarks.fig5_nlo_overlap", {}),
    ("table2", "benchmarks.table2_repeated_dist", {}),
    ("table5", "benchmarks.table5_ablation", {}),
    ("table6", "benchmarks.table6_rs_plus", {}),
    ("table4", "benchmarks.table4_tuning_efficiency", {}),
    ("table1", "benchmarks.table1_cost_decomposition", {}),
    ("fig7_9", "benchmarks.fig7_9_tuning_quality", {}),
]

QUICK = {"kernel", "build", "fig4", "fig5", "table2"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for name, module, kw in BENCHES:
        if args.only and not name.startswith(args.only):
            continue
        if args.quick and name not in QUICK:
            continue
        try:
            mod = importlib.import_module(module)
            if name in ("kernel", "build") and args.quick:
                # quick mode must reach these benches: it selects the
                # small sweep AND routes their JSON to the gitignored
                # quick files instead of clobbering the committed
                # trajectories (BENCH_search.json / BENCH_build.json)
                kw = {**kw, "quick": True}
            mod.run(**kw)
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,{traceback.format_exc(limit=1)!r}",
                  flush=True)
    print(f"# total_seconds,{time.time() - t0:.0f},failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
