"""Fig. 4 — PG construction cost decomposition (Search vs Prune).

Paper: Search dominates (HNSW 86.7%, Vamana 86.8%, NSG 49.0% on Gist) —
the observation motivating ESO.  We report logical #dist shares per phase
from a single-parameter build of each PG."""
from __future__ import annotations

from benchmarks import common
from repro.core import hnsw, nsg, vamana


def run(dataset_name: str = "sift") -> list[str]:
    data, _ = common.dataset(dataset_name)
    rows = []
    builds = {
        "hnsw": lambda: hnsw.build_hnsw(
            data, hnsw.HNSWParams(efc=48, M=12), batch_size=512),
        "vamana": lambda: vamana.build_vamana(
            data, vamana.VamanaParams(L=48, M=12, alpha=1.2),
            batch_size=512),
        "nsg": lambda: nsg.build_nsg(
            data, nsg.NSGParams(K=16, L=48, M=12), batch_size=512),
    }
    out = {}
    for pg, fn in builds.items():
        with common.Timer() as t:
            res = fn()
        c = res.counters
        tot = max(c.total, 1)
        out[pg] = c.as_dict()
        rows.append(common.row(
            f"fig4/{dataset_name}/{pg}",
            t.seconds * 1e6,
            f"search_pct={100*c.search/tot:.1f}%;"
            f"prune_pct={100*c.prune/tot:.1f}%;"
            f"init_pct={100*c.init/tot:.1f}%"))
    common.save_json(f"fig4_{dataset_name}", out)
    return rows


if __name__ == "__main__":
    run()
