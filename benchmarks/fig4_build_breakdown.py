"""Fig. 4 — PG construction cost decomposition (Search vs Prune).

Paper: Search dominates (HNSW 86.7%, Vamana 86.8%, NSG 49.0% on Gist) —
the observation motivating ESO.  We report logical #dist shares per phase
from a single-parameter build of each PG.  Wall seconds use the PR 5
interleaved min-of-reps policy (benchmarks/common.py): the three builders
are being *compared*, so they share timing rounds and report per-builder
mins — a host-load spike can no longer inflate exactly one builder."""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core import hnsw, nsg, vamana


def _synced(fn):
    """Build thunk that blocks on the graph arrays (BuildResult is an
    opaque leaf to ``jax.block_until_ready``, so the timing helper can't
    block on it itself)."""
    def thunk():
        res = fn()
        g = res.g
        jax.block_until_ready(g.ids if hasattr(g, "ids") else g.layer_ids)
        return res
    return thunk


def run(dataset_name: str = "sift", reps: int = 2) -> list[str]:
    data, _ = common.dataset(dataset_name)
    rows = []
    builds = {
        "hnsw": _synced(lambda: hnsw.build_hnsw(
            data, hnsw.HNSWParams(efc=48, M=12), batch_size=512)),
        "vamana": _synced(lambda: vamana.build_vamana(
            data, vamana.VamanaParams(L=48, M=12, alpha=1.2),
            batch_size=512)),
        "nsg": _synced(lambda: nsg.build_nsg(
            data, nsg.NSGParams(K=16, L=48, M=12), batch_size=512)),
    }
    out = {}
    timed = common.time_interleaved(list(builds.values()), reps=reps)
    for (pg, _), (seconds, res) in zip(builds.items(), timed):
        c = res.counters
        tot = max(c.total, 1)
        out[pg] = dict(c.as_dict(), seconds=seconds,
                       timing="interleaved-min-of-reps")
        rows.append(common.row(
            f"fig4/{dataset_name}/{pg}",
            seconds * 1e6,
            f"search_pct={100*c.search/tot:.1f}%;"
            f"prune_pct={100*c.prune/tot:.1f}%;"
            f"init_pct={100*c.init/tot:.1f}%"))
    common.save_json(f"fig4_{dataset_name}", out)
    return rows


if __name__ == "__main__":
    run()
