"""Figs. 7-9 — tuning quality: best QPS at Recall@10 targets per method.

Reads the table4 histories (same tuning runs); the paper's claim is that
FastPGT reaches comparable-or-better QPS at each recall target with much
lower tuning cost."""
from __future__ import annotations

from benchmarks import common

TARGETS = [0.8, 0.9, 0.95]


def run(dataset_name: str = "sift") -> list[str]:
    cached = common.load_json(f"table4_{dataset_name}")
    rows = []
    if not cached:
        rows.append(common.row("fig7_9/missing_table4", 0.0, "run table4 first"))
        return rows
    for key, rec in cached.items():
        if ":" not in key:
            continue
        pg, method = key.split(":")
        objs = rec["objectives"]
        for t in TARGETS:
            qps = max((q for q, r in objs if r >= t), default=0.0)
            rows.append(common.row(
                f"fig7_9/{dataset_name}/{pg}/{method}/recall_{t}",
                rec["summary"]["t_total_s"] * 1e6,
                f"best_qps={qps:.0f}"))
    return rows


if __name__ == "__main__":
    run()
