"""Shared benchmark scaffolding: datasets, timing, CSV rows.

Datasets are synthetic clustered Gaussians mirroring the paper's corpora
dimensionalities, scaled to this container (DESIGN.md §8).  All rows print
as ``name,us_per_call,derived`` per the harness contract; ``derived``
carries the table's key quantity (speedup, ratio, #dist, ...).
"""
from __future__ import annotations

import json
import os
import time

import jax

# persistent compilation cache: repeat runs skip XLA compiles
_CACHE = os.environ.get("JAX_COMPILATION_CACHE", "/tmp/jax_bench_cache")
try:
    jax.config.update("jax_compilation_cache_dir", _CACHE)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
except Exception:
    pass

from repro.core.tuner import estimator  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")

# paper datasets -> laptop-scale stand-ins (true dimensionalities, reduced n:
# wall-time behaviour tracks the paper only when distance compute dominates)
DATASETS = {
    "sift": dict(n=2000, d=128, nq=100, n_clusters=32),   # Sift 128d
    "glove": dict(n=2400, d=100, nq=100, n_clusters=48),  # Glove 100d
}
DEFAULT_DATASET = "sift"

TUNE_KW = dict(budget=12, batch=6, scale=0.15, build_batch_size=512,
               ef_grid=[10, 20, 40, 80], mc_samples=24, timing_reps=1)


def dataset(name: str = DEFAULT_DATASET, seed: int = 0):
    cfg = DATASETS[name]
    return estimator.make_dataset(cfg["n"], cfg["d"], cfg["nq"], seed=seed,
                                  n_clusters=cfg["n_clusters"])


def row(name: str, us_per_call: float, derived) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def save_json(name: str, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=float)


def load_json(name: str):
    path = os.path.join(RESULTS_DIR, name + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def time_min(fn, *args, reps=5):
    """(seconds_per_call, warmup_result) — min over reps.

    Host wall time on this container is ±80% noisy (background load lands
    on whole reps); the min of several reps estimates the uncontended cost,
    where the mean smears contention into the signal.  The warmup result is
    returned so callers needing outputs don't re-run the function."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best, out


def time_interleaved(thunks, reps=5, prime=False):
    """Per-thunk (seconds, warmup_result), timed in interleaved rounds.

    Configurations being *compared* must sample host noise together:
    round r times every config back to back, so a load spike inflates one
    rep of each instead of every rep of whichever config it straddled
    (mean-of-reps sequential timing made PR 3's W=1 vs W=4 CPU comparison
    unstable).  Per-config min over rounds is the reported number — the
    policy the BENCH_*.json trajectories record as
    ``interleaved-min-of-reps``.

    ``prime=True`` runs each thunk once, untimed, immediately before its
    timed rep.  Interleaving fixes *who* precedes each config — every
    config inherits the same neighbor's cache/TLB state each round, which
    at corpus scale is systematically unfair: the routed-search row runs
    behind the S=4 scatter-gather row's ~500 MB sweep and measured 8%
    slower than the identical program self-warm, while the plain row's
    predecessor touches the very arrays it reads.  Priming gives every
    config its own working set in cache, i.e. steady-state repeated-query
    cost — the quantity a serving QPS number means — recorded as
    ``primed-interleaved-min-of-reps``."""
    outs = []
    for fn in thunks:                       # warmup/compile, untimed
        out = fn()
        jax.block_until_ready(out)
        outs.append(out)
    best = [float("inf")] * len(thunks)
    for _ in range(reps):
        for i, fn in enumerate(thunks):
            if prime:
                jax.block_until_ready(fn())
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[i] = min(best[i], time.perf_counter() - t0)
    return list(zip(best, outs))
