"""Fig. 5 — neighbor-list overlap (NLO) between Vamana graphs built with
nearby parameters.  Paper: closer L (resp. alpha) => larger NLO; this is
the structural-overlap fact FastPGT's sharing exploits."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import vamana
from repro.core.graph import INVALID


def nlo(ids_a: np.ndarray, ids_b: np.ndarray) -> float:
    """mean_u |N_a(u) ∩ N_b(u)| / |N_a(u)|"""
    inter = 0.0
    denom = 0.0
    for ra, rb in zip(ids_a, ids_b):
        sa = set(int(x) for x in ra if x != INVALID)
        sb = set(int(x) for x in rb if x != INVALID)
        if not sa:
            continue
        inter += len(sa & sb) / len(sa)
        denom += 1
    return inter / max(denom, 1)


def run(dataset_name: str = "sift") -> list[str]:
    data, _ = common.dataset(dataset_name)
    rows = []
    out = {}

    # vary L at fixed alpha=1.2, M=16 (paper Fig. 5a)
    l_values = [24, 32, 48, 64]
    ps = [vamana.VamanaParams(L=l, M=16, alpha=1.2) for l in l_values]
    with common.Timer() as t:
        res = vamana.build_multi_vamana(data, ps, batch_size=512)
    ref = np.asarray(res.g.ids[1])            # L=32 as the anchor
    for i, l in enumerate(l_values):
        v = nlo(ref, np.asarray(res.g.ids[i]))
        out[f"L={l}"] = v
        rows.append(common.row(f"fig5a/{dataset_name}/L_{l}",
                               t.seconds * 1e6 / 4, f"NLO_vs_L32={v:.3f}"))

    # vary alpha at fixed L=48 (paper Fig. 5b)
    a_values = [1.0, 1.1, 1.2, 1.4]
    ps = [vamana.VamanaParams(L=48, M=16, alpha=a) for a in a_values]
    res = vamana.build_multi_vamana(data, ps, batch_size=512)
    ref = np.asarray(res.g.ids[2])            # alpha=1.2 anchor
    for i, a in enumerate(a_values):
        v = nlo(ref, np.asarray(res.g.ids[i]))
        out[f"alpha={a}"] = v
        rows.append(common.row(f"fig5b/{dataset_name}/alpha_{a}",
                               0.0, f"NLO_vs_a1.2={v:.3f}"))
    common.save_json(f"fig5_{dataset_name}", out)
    return rows


if __name__ == "__main__":
    run()
