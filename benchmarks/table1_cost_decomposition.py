"""Table I — tuning cost decomposition: Recom. vs Est. seconds.

The paper's motivating observation: >=95% of tuning time is parameter
estimation (PG builds + evaluation), not recommendation.  Reads the
table4 result JSON when present (same runs), else runs a reduced sweep.

Fresh sweeps follow the PR 5 interleaved min-of-reps timing policy
(benchmarks/common.py): the methods are being *compared*, so round r runs
every method back to back and each method reports the round with the
smallest total wall time (keeping that round's Recom./Est. split — the
two phases must come from the same run or the percentage is meaningless).
"""
from __future__ import annotations

from benchmarks import common
from repro.core.tuner import fastpgt

METHODS = ["random", "ottertune", "vdtuner", "fastpgt"]


def run(dataset_name: str = "sift", reps: int = 2) -> list[str]:
    cached = common.load_json(f"table4_{dataset_name}")
    rows = []
    fresh = [m for m in METHODS
             if not (cached and f"vamana:{m}" in cached)]
    best: dict[str, fastpgt.TuneResult] = {}
    if fresh:
        data, queries = common.dataset(dataset_name)
        for _ in range(max(1, reps)):          # interleaved min-of-reps
            for method in fresh:
                res = fastpgt.tune("vamana", data, queries, mode=method,
                                   seed=1, **common.TUNE_KW)
                if method not in best or res.t_total < best[method].t_total:
                    best[method] = res
    for method in METHODS:
        if method in best:
            t_rec = best[method].t_recommend
            t_est = best[method].t_estimate
        else:
            s = cached[f"vamana:{method}"]["summary"]
            t_rec = s["t_recommend_s"]
            t_est = s["t_estimate_s"]
        total = t_rec + t_est
        rows.append(common.row(
            f"table1/{dataset_name}/{method}",
            total * 1e6,
            f"recom_s={t_rec:.1f};est_s={t_est:.1f};"
            f"est_pct={100 * t_est / max(total, 1e-9):.2f}%"))
    return rows


if __name__ == "__main__":
    run()
