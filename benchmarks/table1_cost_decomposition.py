"""Table I — tuning cost decomposition: Recom. vs Est. seconds.

The paper's motivating observation: >=95% of tuning time is parameter
estimation (PG builds + evaluation), not recommendation.  Reads the
table4 result JSON when present (same runs), else runs a reduced sweep.
"""
from __future__ import annotations

from benchmarks import common
from repro.core.tuner import fastpgt

METHODS = ["random", "ottertune", "vdtuner", "fastpgt"]


def run(dataset_name: str = "sift") -> list[str]:
    cached = common.load_json(f"table4_{dataset_name}")
    rows = []
    for method in METHODS:
        if cached and f"vamana:{method}" in cached:
            s = cached[f"vamana:{method}"]["summary"]
            t_rec = s["t_recommend_s"]
            t_est = s["t_estimate_s"]
        else:
            data, queries = common.dataset(dataset_name)
            res = fastpgt.tune("vamana", data, queries, mode=method,
                               seed=1, **common.TUNE_KW)
            t_rec, t_est = res.t_recommend, res.t_estimate
        total = t_rec + t_est
        rows.append(common.row(
            f"table1/{dataset_name}/{method}",
            total * 1e6,
            f"recom_s={t_rec:.1f};est_s={t_est:.1f};"
            f"est_pct={100 * t_est / max(total, 1e-9):.2f}%"))
    return rows


if __name__ == "__main__":
    run()
