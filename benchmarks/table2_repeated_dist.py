"""Table II — repeated distance computations across similar parameters.

Builds three HNSW graphs at neighboring (efc, M) settings (paper uses
A/B/C like (300,18)/(300,20)/(300,22)) and reports the sharing ratio: the
fraction of per-graph distance evaluations that the shared build avoided
(paper: ratio_rp > 50%, Search-phase ratio >= 60% on Sift/Glove).

Our accounting (DESIGN.md §3): ratio = 1 - computed/sum_per_graph, i.e.
the fraction of logical evaluations that were cache hits — the same
quantity the paper's intersection ratio measures for m graphs.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import hnsw

SETTINGS = {
    "sift": [(48, 10), (48, 12), (48, 14)],
    "glove": [(64, 14), (64, 16), (64, 18)],
}


def run(dataset_name: str = "sift") -> list[str]:
    data, _ = common.dataset(dataset_name)
    rows = []
    params = [hnsw.HNSWParams(efc=e, M=m)
              for e, m in SETTINGS[dataset_name]]
    with common.Timer() as t:
        res = hnsw.build_multi_hnsw(data, params, batch_size=512)
    c = res.counters
    ratio_total = 1.0 - c.total / max(c.total_base, 1)
    ratio_search = 1.0 - c.search / max(c.search_base, 1)
    ratio_prune = 1.0 - c.prune / max(c.prune_base, 1)
    rows.append(common.row(
        f"table2/{dataset_name}/hnsw",
        t.seconds * 1e6,
        f"ndist={c.total};ratio_rp={ratio_total:.2%};"
        f"ratio_rp_search={ratio_search:.2%};"
        f"ratio_rp_prune={ratio_prune:.2%}"))
    common.save_json(f"table2_{dataset_name}", c.as_dict())
    return rows


if __name__ == "__main__":
    run()
