"""Table IV — tuning efficiency: #dist and wall cost per method x PG.

Reproduces the paper's central comparison (RandomSearch / OtterTune /
VDTuner / FastPGT over HNSW / NSG / Vamana) at container scale.  The
reported claims to match: FastPGT computes ~29-50% of VDTuner's distances
and speeds tuning up ~2x; exact constants are hardware/scale-specific
(DESIGN.md §8) — the *ratios* are the reproduction target.

Also persists the full observation history for fig7_9 (tuning quality).
"""
from __future__ import annotations

from benchmarks import common
from repro.core.tuner import fastpgt

METHODS = ["random", "ottertune", "vdtuner", "fastpgt"]
PGS = ["hnsw", "nsg", "vamana"]


def run(dataset_name: str = "sift", pgs=None, methods=None) -> list[str]:
    data, queries = common.dataset(dataset_name)
    rows = []
    results = {}
    for pg in (pgs or PGS):
        base_dist = None
        base_cost = None
        for method in (methods or METHODS):
            with common.Timer() as t:
                res = fastpgt.tune(pg, data, queries, mode=method, seed=1,
                                   **common.TUNE_KW)
            nd = res.counters.total + res.n_dist_eval
            if method == "vdtuner":
                base_dist, base_cost = nd, res.t_total
            results[f"{pg}:{method}"] = {
                "summary": res.summary(),
                "objectives": res.objectives,
                "cfgs": res.cfgs,
                "n_dist_total": nd,
                "wall_s": t.seconds,
            }
            rows.append(common.row(
                f"table4/{dataset_name}/{pg}/{method}",
                res.t_total * 1e6 / max(len(res.cfgs), 1),
                f"ndist={nd};cost_s={res.t_total:.1f}"))
        if base_dist:
            fp = results[f"{pg}:fastpgt"]
            rows.append(common.row(
                f"table4/{dataset_name}/{pg}/speedup_vs_vdtuner",
                0.0,
                f"dist_frac={fp['n_dist_total']/base_dist:.3f};"
                f"time_speedup={base_cost/max(fp['summary']['t_total_s'],1e-9):.2f}x"))
    common.save_json(f"table4_{dataset_name}", results)
    return rows


if __name__ == "__main__":
    run()
