"""Table VI — model-agnosticism: RandomSearch vs RandomSearch+ (ESO/EPO).

Paper: RS+ costs 34-52% of RS time with 15-21% of the distances."""
from __future__ import annotations

from benchmarks import common
from repro.core.tuner import fastpgt


def run() -> list[str]:
    rows = []
    out = {}
    for ds_name in ("sift", "glove"):
        data, queries = common.dataset(ds_name)
        base = None
        for method in ("random", "random_plus"):
            with common.Timer() as t:
                res = fastpgt.tune("vamana", data, queries, mode=method,
                                   seed=2, **common.TUNE_KW)
            nd = res.counters.total
            if method == "random":
                base = (t.seconds, nd)
            rtc = t.seconds / base[0]
            rdc = nd / base[1]
            out[f"{ds_name}:{method}"] = {
                "cost_s": t.seconds, "ndist": nd, "rtc": rtc, "rdc": rdc}
            rows.append(common.row(
                f"table6/{ds_name}/{method}",
                t.seconds * 1e6,
                f"ndist={nd};RTC={rtc:.2f};RDC={rdc:.2f}"))
    common.save_json("table6", out)
    return rows


if __name__ == "__main__":
    run()
