"""Kernel-layer microbenchmarks: the Search hot-spot distance kernel, the
flash-attention substrate, and the search-scaling bench (dense vs hash
visited state × expand_width, DESIGN.md §9/§10), timed on this host (CPU
path; the Pallas TPU kernels are exercised in interpret mode by tests and
by the CI smoke lane, not timed here).

The search-scaling bench sweeps n ∈ {10k, 100k, 1M synthetic} × visited
impls × W ∈ {1, 4}, the mesh-partitioned serving profile at
shards ∈ {1, 4} (DESIGN.md §11), the query-routed sweep S=4 × p ∈ {1, 2}
over a kmeans partition (DESIGN.md §13), and the degraded-mode sweep
(0 vs 1 dead shards × scatter-gather/routed, DESIGN.md §14), the
streaming sustained-mutation sweep (insert/delete backlog on a
MutableIndex delta layer, DESIGN.md §15), the quantized-corpus sweep
(fp32 vs sq8 int8 codes + fp32 re-rank, with corpus residency bytes,
DESIGN.md §16), and audits the traced
jaxpr: in hash mode (and in the sharded path at S > 1) no intermediate
array may carry a corpus-sized dimension — i.e. no (b, n) / (b, m, n)
state is ever materialized — which is the property that makes million-key
serving fit in memory.  Timing is interleaved min-of-reps (host wall time
here is ±80% noisy; see _time_interleaved).

Every run also writes ``BENCH_search.json`` at the repo root (QPS, hops,
#dist, peak search-state bytes per config) so the serving-perf trajectory
is tracked in-tree across PRs instead of living in commit messages.

  PYTHONPATH=src python -m benchmarks.kernel_microbench [--quick]

``--quick`` runs the n=10k slice with one timing rep — the CI smoke lane
runs it under REPRO_PALLAS_INTERPRET=1 so interpret-mode kernel
regressions fail fast.
"""
from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import graph, hashset, search
from repro.core import metric as metric_lib
from repro.kernels import ops

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_search.json")
# quick/CI runs write a separate (gitignored) file so a 10k interpret-mode
# slice can never clobber the committed full trajectory
BENCH_JSON_QUICK = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_search.quick.json")


# Shared timing policy lives in benchmarks/common.py since the build bench
# (DESIGN.md §12) adopted it too; these aliases keep this module's call
# sites and the historical names.
_time = common.time_min
_time_interleaved = common.time_interleaved


def _corpus_sized_shapes(fn, n: int, *args, **kw) -> list[tuple]:
    """Shapes of every traced intermediate carrying a dimension == n.

    Walks the jaxpr (recursing into pjit/while/scan/cond sub-jaxprs) and
    collects equation *output* avals — function inputs (the corpus and the
    graph are legitimately O(n)) are invars and never flagged."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args)
    bad: list[tuple] = []

    def visit_params(val):
        if hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):
            walk(val.jaxpr)                       # ClosedJaxpr
        elif hasattr(val, "eqns"):
            walk(val)                             # Jaxpr
        elif isinstance(val, (tuple, list)):
            for v in val:
                visit_params(v)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                shape = getattr(getattr(v, "aval", None), "shape", ())
                if n in tuple(shape):
                    bad.append(tuple(shape))
            for v in eqn.params.values():
                visit_params(v)

    walk(closed.jaxpr)
    return bad


def search_scaling_rows(sizes=(10_000, 100_000, 1_000_000), *,
                        widths=(1, 4), shard_counts=(1, 4),
                        routed_ps=(1, 2), reps=5
                        ) -> tuple[list[str], list[dict]]:
    """Search memory/QPS scaling: (dense | hash visited state) × width W,
    the mesh-partitioned serving profile at shards ∈ {1, 4} (DESIGN.md
    §11 — the S=1 row isolates shard_map overhead vs the plain path), and
    the query-routed sweep S=4 × p ∈ {1, 2} over a kmeans partition
    (DESIGN.md §13 — the configuration that turns sharding from a capacity
    win into a throughput win; on this host it takes the fused flat-graph
    program, on an S-device mesh the same call routes per device), and the
    quantized sweep quantize ∈ {none, sq8} on the serving profile
    (DESIGN.md §16 — ``corpus_bytes`` tracks the ~4× residency cut).

    Synthetic corpora: an 8-blob Gaussian mixture (unit spread, the regime
    where centroid routing is meaningful — pure isotropic noise spreads
    every query's neighbors over all shards and no router can help) with
    random regular graphs (graph *quality* is irrelevant to the memory/
    time profile being measured; the recall-preservation bar for routing
    on real built graphs is tests/test_sharded_search.py's n=10k slow
    test).  Reports QPS, hop count, #dist, the analytic peak search-state
    bytes per query batch (visited + V_delta — the quantity DESIGN.md §9
    tabulates; process RSS is a lifetime high-water mark and would
    misattribute earlier configs' peaks, so it is deliberately not
    reported per row), and recall@k against exact ground truth on a wider
    64-query probe batch (8 timing queries × k would quantize recall at
    1/80 — coarser than the 0.01 routed-vs-unsharded comparison this
    column exists to record).  All configs of one corpus size are timed in
    interleaved min-of-reps rounds (``_time_interleaved``) so host-load
    spikes don't bias the cross-config comparison.  Returns (csv rows,
    json records); the hash/ef=32 configs are the serving profile the
    PR-over-PR trajectory in BENCH_search.json tracks.
    """
    from repro.core import eval as evallib

    rows: list[str] = []
    records: list[dict] = []
    b, bq, d, deg, k, ef = 8, 64, 32, 16, 10, 32
    n_blobs = 8
    r = np.random.default_rng(0)
    centers = r.normal(size=(n_blobs, d)) * 3.0
    for n in sizes:
        data = jnp.asarray(
            centers[r.integers(0, n_blobs, n)] + r.normal(size=(n, d)),
            jnp.float32)
        adj = graph.random_knng_ids(0, n, deg)[None]       # (1, n, deg)
        queries = data[:b] + 0.1 * jnp.asarray(
            r.normal(size=(b, d)), jnp.float32)
        rq = data[r.integers(0, n, bq)] + 0.1 * jnp.asarray(
            r.normal(size=(bq, d)), jnp.float32)           # recall probe
        gt = evallib.ground_truth(data, rq, k)
        cfgs: list[dict] = []
        for impl in ("dense", "hash"):
            for w in widths:
                def f(impl=impl, w=w, q=queries):
                    return search.knn_search(adj, data, q, k, ef, 0,
                                             visited_impl=impl,
                                             expand_width=w)
                linear = _corpus_sized_shapes(f, n)
                if impl == "hash":
                    assert not linear, (
                        f"hash mode materialized corpus-sized state: "
                        f"{linear}")
                    hops_bound = search.default_max_hops(ef, w)
                    slots = hashset.auto_slots(hops_bound, w * deg)
                    state_bytes = b * slots * 4
                else:
                    assert linear, (
                        "audit sanity: dense mode must show (b,m,n)")
                    state_bytes = b * n               # visited bool[b, 1, n]
                cfgs.append(dict(
                    name=f"search_scaling/{impl}/W={w}/n={n}", fn=f,
                    recall_fn=functools.partial(f, q=rq),
                    rec=dict(path="plain", n=n, impl=impl, expand_width=w,
                             num_shards=1, ef=ef, k=k, batch=b, degree=deg,
                             state_bytes=state_bytes)))

        def shard_graph(local):
            return graph.random_knng_ids(0, local.shape[0], deg), 0

        for s in shard_counts:
            sg = graph.partition(data, s, build_fn=shard_graph)

            def f(sg=sg, q=queries):
                return search.sharded_knn_search(
                    sg, q, k, ef, visited_impl="hash", expand_width=4)
            if s > 1:
                linear = _corpus_sized_shapes(f, n)
                assert not linear, (
                    f"sharded search materialized corpus-sized state: "
                    f"{linear}")      # per-shard (n/S) arrays are the point
            slots = hashset.auto_slots(search.default_max_hops(ef, 4),
                                       4 * deg)
            # path="sharded" disambiguates the S=1 row from the plain
            # hash/W=4 row (same config keys, different execution path)
            cfgs.append(dict(
                name=f"search_scaling/sharded/S={s}/W=4/n={n}", fn=f,
                recall_fn=functools.partial(f, q=rq),
                rec=dict(path="sharded", n=n, impl="hash", expand_width=4,
                         num_shards=s, ef=ef, k=k, batch=b, degree=deg,
                         state_bytes=b * slots * 4 * s)))
        sr = max(shard_counts)
        sgk = graph.partition(data, sr, build_fn=shard_graph,
                              assignment="kmeans")
        slots = hashset.auto_slots(search.default_max_hops(ef, 4), 4 * deg)
        for p in routed_ps:
            def f(p=p, q=queries):
                return search.sharded_knn_search(
                    sgk, q, k, ef, visited_impl="hash", expand_width=4,
                    routed_shards=p)
            # no jaxpr audit here: the fused routed program views the
            # stacked shard arrays as flat (aliasing reshapes the audit
            # would flag as corpus-sized); the per-device residency claim
            # belongs to the mesh path, which CI's multi-device lane runs.
            cfgs.append(dict(
                name=f"search_scaling/routed/S={sr}/p={p}/n={n}", fn=f,
                recall_fn=functools.partial(f, q=rq),
                rec=dict(path="routed", n=n, impl="hash", expand_width=4,
                         num_shards=sr, routed_shards=p, assign="kmeans",
                         ef=ef, k=k, batch=b, degree=deg,
                         state_bytes=b * p * slots * 4)))
        # Degraded-mode sweep (DESIGN.md §14): the same kmeans partition
        # with 0 vs 1 dead shards, on both execution strategies.  The
        # dead=1 rows record what serving actually costs and returns while
        # routing around a failed shard — qps should rise (less work) and
        # recall fall by roughly the dead shard's ground-truth share
        # (tests/test_resilience.py pins the bound); the dead=0 rows are
        # the in-family baselines timed in the same interleaved rounds.
        # The mask comes from the same ShardHealth harness the chaos lane
        # drives, so bench rows and guarding tests inject identical state.
        from repro.serve import resilience
        health = resilience.ShardHealth.fresh(sr)
        health.kill(0)
        for dead, mask in ((0, None), (1, health.mask())):
            for strat, skw in (("scatter_gather", {}),
                               ("routed", {"routed_shards": 2})):
                def f(mask=mask, skw=skw, q=queries):
                    return search.sharded_knn_search(
                        sgk, q, k, ef, visited_impl="hash", expand_width=4,
                        shard_mask=mask, **skw)
                live = sr - dead
                sb = (b * skw["routed_shards"] * slots * 4 if skw
                      else b * slots * 4 * live)
                cfgs.append(dict(
                    name=(f"search_scaling/degraded/{strat}/dead={dead}"
                          f"/n={n}"), fn=f,
                    recall_fn=functools.partial(f, q=rq),
                    rec=dict(path="degraded", strategy=strat,
                             dead_shards=dead, n=n, impl="hash",
                             expand_width=4, num_shards=sr, assign="kmeans",
                             routed_shards=skw.get("routed_shards"),
                             ef=ef, k=k, batch=b, degree=deg,
                             state_bytes=sb)))
        # Quantized sweep (DESIGN.md §16): the serving profile (hash/W=4)
        # with the corpus held fp32 vs sq8.  ``corpus_bytes`` records the
        # residency the mode exists to shrink (~4×: int8 codes + per-dim
        # fp32 scale + per-row fp32 norms vs fp32 rows); qps on this CPU
        # host prices the ADC + ef-wide fp32 re-rank overhead, not the
        # bandwidth win a real accelerator sees.  The quantize="none" row
        # dispatches the identical program as plain hash/W=4 — it is timed
        # again here so the none-vs-sq8 delta shares interleaved rounds.
        quant = metric_lib.resolve("l2").prepare_quantized(data)
        slots = hashset.auto_slots(search.default_max_hops(ef, 4), 4 * deg)
        for qz in ("none", "sq8"):
            def f(qz=qz, q=queries):
                return search.knn_search(
                    adj, data, q, k, ef, 0, visited_impl="hash",
                    expand_width=4, quantize=qz,
                    quant=quant if qz == "sq8" else None)
            corpus_bytes = (int(quant.codes.nbytes) + int(quant.scale.nbytes)
                            + int(quant.norms.nbytes)
                            if qz == "sq8" else int(data.nbytes))
            cfgs.append(dict(
                name=f"search_scaling/quantized/{qz}/n={n}", fn=f,
                recall_fn=functools.partial(f, q=rq),
                rec=dict(path="quantized", quantize=qz, n=n, impl="hash",
                         expand_width=4, num_shards=1, ef=ef, k=k, batch=b,
                         degree=deg, corpus_bytes=corpus_bytes,
                         state_bytes=b * slots * 4)))
        timed = _time_interleaved([c["fn"] for c in cfgs], reps=reps,
                                  prime=True)
        for cfg, (sec, res) in zip(cfgs, timed):
            rres = cfg["recall_fn"]()
            recall = round(evallib.recall_at_k(rres.pool_ids[:, :k], gt), 4)
            rec = dict(cfg["rec"], qps=round(b / sec, 1),
                       us_per_batch=round(sec * 1e6, 1),
                       hops=int(res.hops), n_dist=int(res.n_computed),
                       recall=recall)
            records.append(rec)
            rows.append(common.row(
                cfg["name"], sec * 1e6,
                f"qps={rec['qps']} hops={rec['hops']} "
                f"ndist={rec['n_dist']} recall={recall} "
                f"state_bytes={rec['state_bytes']}"))
    return rows, records


def streaming_mutation_rows(n: int = 1_000_000, *, reps: int = 5,
                            quick: bool = False
                            ) -> tuple[list[str], list[dict]]:
    """Sustained-mutation sweep (DESIGN.md §15): insert load × delete load
    on a streaming MutableIndex over the S=4 kmeans partition at n.

    Each config wraps the same main index and applies a mutation backlog —
    inserts fill the delta layer (past ``DELTA_GRAPH_MIN`` the delta
    Vamana kicks in, so the full run times the graph+brute-tail path, the
    quick run the brute-only path), deletes tombstone random main rows —
    then the *steady-state* search cost under that backlog is timed with
    the same primed interleaved min-of-reps policy as every other row
    (mutating mid-timing would time host mutation bookkeeping, not
    serving).  The (0, 0) config is the pristine baseline: it dispatches
    the wrapped index's own cached program, so its qps is directly
    comparable to the path="sharded" rows.  ``recall`` is measured against
    exact ground truth over the LIVE corpus (inserts included, deleted
    rows excluded) on the wider 64-query probe; ``recall_drift`` is the
    pristine baseline's recall minus the config's — the quantity the
    streaming acceptance test bounds at 0.02.  Compaction never fires
    inside the sweep (capacity above the backlog, threshold 1.0): these
    rows record the delta/tombstone overhead compaction exists to bound.
    Shard graphs are random-regular like the scaling rows (the profile is
    memory/time, not graph quality) and compaction's build hook is the
    same cheap generator, so the sweep stays build-cost-free at n=1M.
    """
    from repro.core import eval as evallib
    from repro.core import vamana
    from repro.serve import retrieval, streaming

    rows: list[str] = []
    records: list[dict] = []
    b, bq, d, deg, k, ef = 8, 64, 32, 16, 10, 32
    S, n_blobs = 4, 8
    r = np.random.default_rng(1)
    centers = r.normal(size=(n_blobs, d)) * 3.0
    data_np = (centers[r.integers(0, n_blobs, n)]
               + r.normal(size=(n, d))).astype(np.float32)
    data = jnp.asarray(data_np)
    queries = data[:b] + 0.1 * jnp.asarray(
        r.normal(size=(b, d)), jnp.float32)
    rq = data[r.integers(0, n, bq)] + 0.1 * jnp.asarray(
        r.normal(size=(bq, d)), jnp.float32)       # recall probe

    def shard_graph(local):
        return graph.random_knng_ids(0, np.asarray(local).shape[0], deg), 0

    sgk = graph.partition(data, S, build_fn=shard_graph,
                          assignment="kmeans")
    entry = int(sgk.global_ids[0][int(sgk.entries[0])])
    idx = retrieval.RetrievalIndex(
        graph_ids=None, keys=data, values=data, search_keys=None,
        entry=entry, params=vamana.VamanaParams(L=24, M=deg, alpha=1.2),
        metric="l2", shards=sgk,
        provenance=dict(num_shards=S, assign="kmeans", seed=0))
    load = 64 if quick else 1024
    loads = [(0, 0), (load, 0), (0, load), (load, load)]
    ins_vecs = (centers[r.integers(0, n_blobs, load)]
                + r.normal(size=(load, d))).astype(np.float32)
    del_rows = r.choice(n, size=load, replace=False)
    cfgs: list[dict] = []
    for ins, dels in loads:
        mi = streaming.MutableIndex(
            idx, delta_capacity=max(ins, 1) + 1,
            tombstone_compact_frac=1.0, build_fn=shard_graph)
        for v in ins_vecs[:ins]:
            mi.insert(v)
        for row_i in del_rows[:dels]:
            mi.delete(int(row_i))

        def f(mi=mi, q=queries):
            return mi.attention_batched(q, top_k=k, ef=ef)[1]
        # live-corpus ground truth in external-id space
        live = np.ones(n, bool)
        live[del_rows[:dels]] = False
        live_rows = np.nonzero(live)[0]
        exts = np.concatenate([live_rows, np.arange(n, n + ins)])
        gt_rows = evallib.ground_truth(
            jnp.asarray(np.concatenate([data_np[live_rows],
                                        ins_vecs[:ins]])), rq, k)
        gt_ext = jnp.asarray(exts[np.asarray(gt_rows)])
        cfgs.append(dict(
            name=f"search_scaling/streaming/ins={ins}/del={dels}/n={n}",
            fn=f, mi=mi, gt=gt_ext,
            rec=dict(path="streaming", n=n, impl="hash", expand_width=4,
                     num_shards=S, assign="kmeans", ef=ef, k=k, batch=b,
                     degree=deg, inserts=ins, deletes=dels,
                     delta_graph_nodes=mi._dg_n)))
    timed = _time_interleaved([c["fn"] for c in cfgs], reps=reps,
                              prime=True)
    base_recall = None
    for cfg, (sec, res) in zip(cfgs, timed):
        rres = cfg["mi"].attention_batched(rq, top_k=k, ef=ef)[1]
        recall = round(evallib.recall_at_k(rres.pool_ids[:, :k],
                                           cfg["gt"]), 4)
        if base_recall is None:
            base_recall = recall                   # the (0, 0) config
        rec = dict(cfg["rec"], qps=round(b / sec, 1),
                   us_per_batch=round(sec * 1e6, 1),
                   hops=int(res.hops), n_dist=int(res.n_computed),
                   recall=recall,
                   recall_drift=round(base_recall - recall, 4))
        records.append(rec)
        rows.append(common.row(
            cfg["name"], sec * 1e6,
            f"qps={rec['qps']} hops={rec['hops']} ndist={rec['n_dist']} "
            f"recall={recall} drift={rec['recall_drift']}"))
    return rows, records


def write_bench_json(records: list[dict], *, quick: bool = False) -> None:
    """Persist the search-scaling records so the perf trajectory is
    diffable across PRs.  Full runs write the committed repo-root
    ``BENCH_search.json``; quick/CI runs write the gitignored
    ``BENCH_search.quick.json`` (tagged, so a 10k interpret-mode slice is
    never mistaken for — or committed over — the full trajectory)."""
    payload = {
        "bench": "search_scaling",
        "contract": "serving config = hash/ef=32; compare qps across PRs. "
                    "Rows before PR 5 were mean-of-reps; qps is not "
                    "comparable across that boundary. PR 7 switched the "
                    "corpus from isotropic noise to an 8-blob mixture "
                    "(routing regime) and added the recall column — "
                    "another qps-comparability boundary. recall is "
                    "measured on random regular graphs (near 0 by design "
                    "at large n); its job is the routed-vs-unsharded "
                    "delta, the absolute bar lives in the slow tests. "
                    "PR 7 also primed the timing rounds (see "
                    "common.time_interleaved): qps is steady-state "
                    "repeated-query cost, not follow-the-neighbor cache "
                    "state. PR 8 added the degraded-mode rows "
                    "(path=degraded): recall there is against the FULL "
                    "ground truth, so dead=1 rows are expected to sit "
                    "below their dead=0 baselines by about the dead "
                    "shard's ground-truth share. PR 9 added the "
                    "streaming sustained-mutation rows (path=streaming): "
                    "qps under an un-compacted insert/delete backlog; "
                    "recall there is against the LIVE corpus (inserts "
                    "included, deleted rows excluded) and recall_drift "
                    "is vs the pristine ins=0/del=0 baseline row. PR 10 "
                    "added the quantized rows (path=quantized): the "
                    "serving profile with the corpus fp32 vs sq8 int8 "
                    "codes + fp32 re-rank; corpus_bytes records the ~4x "
                    "residency reduction, and on this CPU host the sq8 "
                    "qps prices ADC+re-rank overhead, not the bandwidth "
                    "win an accelerator sees",
        "timing": {"policy": "primed-interleaved-min-of-reps",
                   "noise": "host wall time is +/-80% under load; per-n "
                            "config sets share timing rounds and report "
                            "the per-config min"},
        "backend": jax.default_backend(),
        "num_devices": jax.device_count(),
        "mode": "quick" if quick else "full",
        "rows": records,
    }
    with open(BENCH_JSON_QUICK if quick else BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def run(quick: bool = False) -> list[str]:
    rows = []
    r = np.random.default_rng(0)
    shapes = [(256, 2048, 32)] if quick else [(256, 2048, 32),
                                              (1024, 8192, 128)]
    for nq, nx, d in shapes:
        q = jnp.asarray(r.normal(size=(nq, d)), jnp.float32)
        x = jnp.asarray(r.normal(size=(nx, d)), jnp.float32)
        f = jax.jit(ops.l2_distance)
        sec, _ = _time(f, q, x)
        gflops = 2 * nq * nx * d / sec / 1e9
        rows.append(common.row(
            f"kernel/l2_distance/{nq}x{nx}x{d}", sec * 1e6,
            f"gflops={gflops:.1f}"))
        for metric in ("ip", "cosine"):
            f = jax.jit(lambda q, x, m=metric: ops.pairwise_distance(q, x, m))
            sec, _ = _time(f, q, x)
            gflops = 2 * nq * nx * d / sec / 1e9
            rows.append(common.row(
                f"kernel/{metric}_distance/{nq}x{nx}x{d}", sec * 1e6,
                f"gflops={gflops:.1f}"))
    # gather-distance: the in-loop search kernel (b queries × W·Mx slab)
    for b, kx, d in [(8, 64, 32)] if quick else [(8, 64, 32), (64, 256, 128)]:
        u = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
        c = jnp.asarray(r.normal(size=(b, kx, d)), jnp.float32)
        for metric in ("l2", "ip"):
            f = jax.jit(lambda u, c, m=metric: ops.gather_distance(u, c,
                                                                   metric=m))
            sec, _ = _time(f, u, c)
            rows.append(common.row(
                f"kernel/gather_{metric}/{b}x{kx}x{d}", sec * 1e6,
                f"gflops={2 * b * kx * d / sec / 1e9:.2f}"))
    fa_shapes = [(2, 4, 1024, 64)] if quick else [(2, 4, 1024, 64),
                                                  (1, 8, 4096, 128)]
    for b, h, s, dh in fa_shapes:
        q = jnp.asarray(r.normal(size=(b, h, s, dh)), jnp.float32)
        f = jax.jit(lambda q: ops.flash_attention(q, q, q, causal=True))
        sec, _ = _time(f, q, reps=3)
        gflops = 4 * b * h * s * s * dh / 2 / sec / 1e9   # causal half
        rows.append(common.row(
            f"kernel/flash_attention/{b}x{h}x{s}x{dh}", sec * 1e6,
            f"gflops={gflops:.1f}"))
    if quick:
        srows, records = search_scaling_rows(sizes=(10_000,), reps=1)
        mrows, mrecords = streaming_mutation_rows(10_000, reps=1,
                                                  quick=True)
    else:
        srows, records = search_scaling_rows()
        mrows, mrecords = streaming_mutation_rows()
    rows += srows + mrows
    write_bench_json(records + mrecords, quick=quick)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="n=10k slice, 1 rep (CI smoke lane)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
