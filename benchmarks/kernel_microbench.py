"""Kernel-layer microbenchmarks: the Search hot-spot distance kernel and
the flash-attention substrate, timed on this host (CPU path; the Pallas
TPU kernels are exercised in interpret mode by tests, not timed here)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> list[str]:
    rows = []
    r = np.random.default_rng(0)
    for nq, nx, d in [(256, 2048, 32), (1024, 8192, 128)]:
        q = jnp.asarray(r.normal(size=(nq, d)), jnp.float32)
        x = jnp.asarray(r.normal(size=(nx, d)), jnp.float32)
        f = jax.jit(ops.l2_distance)
        sec = _time(f, q, x)
        gflops = 2 * nq * nx * d / sec / 1e9
        rows.append(common.row(
            f"kernel/l2_distance/{nq}x{nx}x{d}", sec * 1e6,
            f"gflops={gflops:.1f}"))
        for metric in ("ip", "cosine"):
            f = jax.jit(lambda q, x, m=metric: ops.pairwise_distance(q, x, m))
            sec = _time(f, q, x)
            gflops = 2 * nq * nx * d / sec / 1e9
            rows.append(common.row(
                f"kernel/{metric}_distance/{nq}x{nx}x{d}", sec * 1e6,
                f"gflops={gflops:.1f}"))
    for b, h, s, dh in [(2, 4, 1024, 64), (1, 8, 4096, 128)]:
        q = jnp.asarray(r.normal(size=(b, h, s, dh)), jnp.float32)
        f = jax.jit(lambda q: ops.flash_attention(q, q, q, causal=True))
        sec = _time(f, q, reps=3)
        gflops = 4 * b * h * s * s * dh / 2 / sec / 1e9   # causal half
        rows.append(common.row(
            f"kernel/flash_attention/{b}x{h}x{s}x{dh}", sec * 1e6,
            f"gflops={gflops:.1f}"))
    return rows


if __name__ == "__main__":
    run()
