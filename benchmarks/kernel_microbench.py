"""Kernel-layer microbenchmarks: the Search hot-spot distance kernel, the
flash-attention substrate, and the search-scaling bench (dense vs hash
visited state, DESIGN.md §9), timed on this host (CPU path; the Pallas
TPU kernels are exercised in interpret mode by tests, not timed here).

The search-scaling bench sweeps n ∈ {10k, 100k, 1M synthetic} × visited
impls and audits the traced jaxpr: in hash mode no intermediate array may
carry a corpus-sized dimension — i.e. no (b, n) / (b, m, n) state is ever
materialized — which is the property that makes million-key serving fit
in memory."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import graph, hashset, search
from repro.kernels import ops


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _corpus_sized_shapes(fn, n: int, *args, **kw) -> list[tuple]:
    """Shapes of every traced intermediate carrying a dimension == n.

    Walks the jaxpr (recursing into pjit/while/scan/cond sub-jaxprs) and
    collects equation *output* avals — function inputs (the corpus and the
    graph are legitimately O(n)) are invars and never flagged."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args)
    bad: list[tuple] = []

    def visit_params(val):
        if hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):
            walk(val.jaxpr)                       # ClosedJaxpr
        elif hasattr(val, "eqns"):
            walk(val)                             # Jaxpr
        elif isinstance(val, (tuple, list)):
            for v in val:
                visit_params(v)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                shape = getattr(getattr(v, "aval", None), "shape", ())
                if n in tuple(shape):
                    bad.append(tuple(shape))
            for v in eqn.params.values():
                visit_params(v)

    walk(closed.jaxpr)
    return bad


def search_scaling_rows(sizes=(10_000, 100_000, 1_000_000)) -> list[str]:
    """Search memory/QPS scaling: dense bitmap vs hash-set visited state.

    Synthetic corpora (random data + random regular graph — graph quality
    is irrelevant to the memory/time profile being measured).  Reports QPS
    and the analytic peak search-state bytes per query batch (visited +
    V_delta — the quantity DESIGN.md §9 tabulates; process RSS is a
    lifetime high-water mark and would misattribute earlier configs'
    peaks, so it is deliberately not reported per row)."""
    rows = []
    b, d, deg, k, ef, hops = 8, 32, 16, 10, 32, 64
    r = np.random.default_rng(0)
    for n in sizes:
        data = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
        adj = graph.random_knng_ids(0, n, deg)[None]       # (1, n, deg)
        queries = data[:b] + 0.1 * jnp.asarray(
            r.normal(size=(b, d)), jnp.float32)
        for impl in ("dense", "hash"):
            def f(adj, data, queries, impl=impl):
                return search.knn_search(adj, data, queries, k, ef, 0,
                                         max_hops=hops, visited_impl=impl)
            linear = _corpus_sized_shapes(f, n, adj, data, queries)
            if impl == "hash":
                assert not linear, (
                    f"hash mode materialized corpus-sized state: {linear}")
                slots = hashset.auto_slots(hops, deg)
                state_bytes = b * slots * 4
            else:
                assert linear, "audit sanity: dense mode must show (b,m,n)"
                state_bytes = b * n                   # visited bool[b, 1, n]
            sec = _time(f, adj, data, queries, reps=3)
            rows.append(common.row(
                f"search_scaling/{impl}/n={n}", sec * 1e6,
                f"qps={b / sec:.1f} state_bytes={state_bytes}"))
    return rows


def run() -> list[str]:
    rows = []
    r = np.random.default_rng(0)
    for nq, nx, d in [(256, 2048, 32), (1024, 8192, 128)]:
        q = jnp.asarray(r.normal(size=(nq, d)), jnp.float32)
        x = jnp.asarray(r.normal(size=(nx, d)), jnp.float32)
        f = jax.jit(ops.l2_distance)
        sec = _time(f, q, x)
        gflops = 2 * nq * nx * d / sec / 1e9
        rows.append(common.row(
            f"kernel/l2_distance/{nq}x{nx}x{d}", sec * 1e6,
            f"gflops={gflops:.1f}"))
        for metric in ("ip", "cosine"):
            f = jax.jit(lambda q, x, m=metric: ops.pairwise_distance(q, x, m))
            sec = _time(f, q, x)
            gflops = 2 * nq * nx * d / sec / 1e9
            rows.append(common.row(
                f"kernel/{metric}_distance/{nq}x{nx}x{d}", sec * 1e6,
                f"gflops={gflops:.1f}"))
    for b, h, s, dh in [(2, 4, 1024, 64), (1, 8, 4096, 128)]:
        q = jnp.asarray(r.normal(size=(b, h, s, dh)), jnp.float32)
        f = jax.jit(lambda q: ops.flash_attention(q, q, q, causal=True))
        sec = _time(f, q, reps=3)
        gflops = 4 * b * h * s * s * dh / 2 / sec / 1e9   # causal half
        rows.append(common.row(
            f"kernel/flash_attention/{b}x{h}x{s}x{dh}", sec * 1e6,
            f"gflops={gflops:.1f}"))
    rows += search_scaling_rows()
    return rows


if __name__ == "__main__":
    run()
