"""Table V — ESO/EPO ablation: RTC (relative tuning cost) and RDC
(relative distance computations) for configs (I) neither, (II) ESO only,
(III) ESO+EPO, per PG type.  Paper: RDC 0.18-0.57, RTC 0.47-0.54."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import eval as evallib
from repro.core.tuner import estimator
from repro.core.tuner import params as pspace

CONFIGS = [("I", False, False), ("II", True, False), ("III", True, True)]


def _batch_cfgs(pg: str, seed: int = 0):
    sp = pspace.space(pg, scale=0.15)
    rng = np.random.default_rng(seed)
    center = sp.sample(rng, 1)[0]
    return [sp.decode(sp.perturb(rng, center, 0.06)) for _ in range(6)]


def run(dataset_name: str = "msong") -> list[str]:
    # paper reports Table V on Msong; our stand-in uses the glove-like set
    ds_name = "glove" if dataset_name == "msong" else dataset_name
    data, queries = common.dataset(ds_name)
    gt = evallib.ground_truth(data, queries, 10)
    rows = []
    out = {}
    for pg in ("nsg", "hnsw", "vamana"):
        cfgs = _batch_cfgs(pg)
        base_cost = base_dist = None
        for name, eso, epo in CONFIGS:
            with common.Timer() as t:
                rec = estimator.estimate(
                    pg, data, queries, gt, cfgs, group_size=6,
                    use_eso=eso, use_epo=epo, ef_grid=[10, 20],
                    build_batch_size=512)
            nd = rec.counters.total
            if name == "I":
                base_cost, base_dist = t.seconds, nd
            rtc = t.seconds / base_cost
            rdc = nd / base_dist
            out[f"{pg}:{name}"] = {"cost_s": t.seconds, "ndist": nd,
                                   "rtc": rtc, "rdc": rdc}
            rows.append(common.row(
                f"table5/{pg}/config_{name}",
                t.seconds * 1e6,
                f"ndist={nd};RTC={rtc:.2f};RDC={rdc:.2f}"))
    common.save_json("table5", out)
    return rows


if __name__ == "__main__":
    run()
