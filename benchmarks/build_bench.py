"""Build-path scaling bench: fused single-dispatch batch steps vs the
per_batch host loop (DESIGN.md §12).

Sweeps n × m graphs × build_impl for the multi-Vamana builder and records,
per configuration: build seconds (interleaved min-of-reps — both impls of
one (n, m) cell share timing rounds), the measured Python-level jitted
dispatch counts, and the logical #dist counters.  The bench *asserts* the
n_dist contract (DESIGN.md §12): per counter field, fused may deviate
from per_batch by at most ``CTR_RTOL`` relative — the two impls trace the
same stage functions, but the per_batch path runs the prune stage's
candidate-distance reduction as an eager op while the fused step compiles
it, and XLA's different accumulation orders flip ppm-level near-ties in
the dominance checks (measured ≤4e-5 of prune checks at n=20k; the same
mechanism behind the pre-§12 prototype's 30-distance gap at n=100k).
Each fused row records the exact per-field ``counter_delta``.

Dispatch counts are measured by wrapping the module-level jitted callables
(``search.beam_search``, ``prune.rng_prune``, ``commit.add_reverse_edges``,
``core/build.*``) with counting shims AFTER a warmup build, so trace-time
inner calls don't inflate the numbers: post-warmup, the per_batch loop
makes ``1 + 2m`` jitted calls per batch (search + m prunes + m reverse
commits) plus eager-op traffic, while the fused Vamana pass makes exactly
ONE jitted call for the whole build (``fused_vamana_pass``'s
``lax.fori_loop`` over batches).  Counting runs on a small corpus — the
per-batch dispatch structure is shape-independent.

Every full run writes ``BENCH_build.json`` at the repo root (committed:
the build-perf trajectory is a review diff, not a commit-message claim);
``--quick`` runs a small slice and writes the gitignored
``BENCH_build.quick.json`` instead.

  PYTHONPATH=src python -m benchmarks.build_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from benchmarks import common
from repro.core import build as build_lib
from repro.core import commit, prune, search, vamana
from repro.core.tuner import estimator

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_build.json")
BENCH_JSON_QUICK = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_build.quick.json")

D = 32
BATCH = 256
CTR_RTOL = 1e-4         # max relative fused-vs-per_batch counter deviation
COUNT_N = 2048          # corpus size for dispatch counting (structure only)
SWEEP_FULL = [(20_000, 2), (20_000, 4), (100_000, 4)]
SWEEP_QUICK = [(512, 2)]        # CI smoke budget: interpret-mode kernels

# m distinct parameter sets, EPO-sorted by alpha like a tuner group
_PARAM_BANK = [(32, 16, 1.0), (48, 24, 1.1), (32, 16, 1.2), (48, 24, 1.3)]


def build_params(m: int) -> list:
    return [vamana.VamanaParams(L, M, a) for L, M, a in _PARAM_BANK[:m]]


# Module-level jitted callables a build may dispatch from Python.  Inner
# functions a fused step *traces* (e.g. rng_prune inside insert_batch) are
# inlined into the one compiled dispatch and correctly count zero here.
DISPATCH_TARGETS = (
    (search, "beam_search"),
    (prune, "rng_prune"),
    (commit, "add_reverse_edges"),
    (build_lib, "insert_batch"),
    (build_lib, "nsg_insert_batch"),
    (build_lib, "fused_vamana_pass"),
)


class _Counting:
    def __init__(self, fn):
        self.fn, self.calls = fn, 0

    def __call__(self, *a, **kw):
        self.calls += 1
        return self.fn(*a, **kw)


def count_dispatches(fn) -> dict[str, int]:
    """Python-level jitted-callable invocation counts during one ``fn()``.

    Call only after a warmup run of the same shapes: a cold call traces,
    and tracing invokes wrapped inner names once while compiling."""
    shims = [(mod, name, _Counting(getattr(mod, name)))
             for mod, name in DISPATCH_TARGETS]
    for mod, name, shim in shims:
        setattr(mod, name, shim)
    try:
        fn()
    finally:
        for mod, name, shim in shims:
            setattr(mod, name, shim.fn)
    return {f"{mod.__name__.rsplit('.', 1)[-1]}.{name}": shim.calls
            for mod, name, shim in shims if shim.calls}


def _build(data, ps, impl):
    res = vamana.build_multi_vamana(data, ps, batch_size=BATCH,
                                    build_impl=impl)
    return res, res.g.ids      # ids rides along so timing can block on it


def build_scaling_rows(sweep, *, reps=2) -> tuple[list[str], list[dict]]:
    rows: list[str] = []
    records: list[dict] = []
    dispatch_cache: dict[tuple[str, int], dict] = {}
    for n, m in sweep:
        data, _ = estimator.make_dataset(n, D, 1, seed=0)
        ps = build_params(m)
        n_batches = -(-n // BATCH)
        impls = list(build_lib.BUILD_IMPLS)
        timed = common.time_interleaved(
            [lambda impl=impl: _build(data, ps, impl) for impl in impls],
            reps=reps)
        ctrs = {impl: res.counters.as_dict()
                for impl, (_, (res, _)) in zip(impls, timed)}
        # the n_dist contract (DESIGN.md §12): per-field relative
        # deviation bounded by CTR_RTOL (eager-vs-compiled FP
        # reassociation in the prune stage flips ppm-level near-ties)
        deltas = {k: ctrs["fused"][k] - ctrs["per_batch"][k]
                  for k in ctrs["per_batch"]}
        for k, dv in deltas.items():
            rel = abs(dv) / max(ctrs["per_batch"][k], 1)
            assert rel <= CTR_RTOL, (
                f"fused/per_batch counter '{k}' deviates {rel:.2e} "
                f"(> {CTR_RTOL}) at n={n} m={m}: {ctrs}")
        sec_of = {impl: sec for impl, (sec, _) in zip(impls, timed)}
        # Counting reuses the sweep corpus when it is already small (the
        # timing warmup compiled those shapes), else a COUNT_N stand-in —
        # per-batch dispatch structure is shape-independent either way.
        count_n = min(COUNT_N, n)
        for impl in impls:
            key = (impl, m, count_n)
            if key not in dispatch_cache:
                cdata = (data if count_n == n
                         else estimator.make_dataset(count_n, D, 1,
                                                     seed=0)[0])
                _build(cdata, ps, impl)                     # warmup/compile
                dispatch_cache[key] = count_dispatches(
                    lambda: _build(cdata, ps, impl))
            disp = dispatch_cache[key]
            cb = -(-count_n // BATCH)                # counting-run batches
            sec = sec_of[impl]
            rec = dict(
                n=n, m=m, impl=impl, d=D, batch_size=BATCH,
                n_batches=n_batches, seconds=round(sec, 4),
                n_dist=ctrs[impl]["total"],
                counters=ctrs[impl], dispatches=disp,
                **({"counter_delta": deltas} if impl == "fused" else {}),
                dispatches_per_batch=round(sum(disp.values()) / cb, 3),
                speedup_vs_per_batch=round(sec_of["per_batch"] / sec, 3))
            records.append(rec)
            rows.append(common.row(
                f"build/{impl}/n={n}/m={m}", sec * 1e6,
                f"speedup={rec['speedup_vs_per_batch']} "
                f"dispatch_per_batch={rec['dispatches_per_batch']} "
                f"ndist={rec['n_dist']}"))
    return rows, records


def write_bench_json(records: list[dict], *, quick: bool = False) -> None:
    payload = {
        "bench": "build_scaling",
        "contract": "fused traces the per_batch stage functions; per "
                    "counter field |fused - per_batch| / per_batch <= "
                    f"{CTR_RTOL} (asserted per cell, exact deltas in "
                    "each fused row's counter_delta). Residual ppm-level "
                    "deviation is eager-vs-compiled FP reassociation in "
                    "the prune stage's candidate-distance reduction — "
                    "the same mechanism behind the pre-S12 prototype's "
                    "30-distance n_dist gap at n=100k (DESIGN.md S12). "
                    "fused must beat per_batch wall-clock for m >= 4 at "
                    "n >= 100k; compare seconds across PRs per "
                    "(n, m, impl) cell",
        "timing": {"policy": "interleaved-min-of-reps",
                   "noise": "host wall time is +/-80% under load; both "
                            "impls of one (n, m) cell share timing rounds "
                            "and report the per-impl min"},
        "dispatch_counting": "python-level jitted-callable invocations "
                             "after warmup (trace-time calls excluded), "
                             "measured on a n<=2048 corpus — per-batch "
                             "dispatch structure is shape-independent",
        "backend": jax.default_backend(),
        "num_devices": jax.device_count(),
        "mode": "quick" if quick else "full",
        "rows": records,
    }
    with open(BENCH_JSON_QUICK if quick else BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def run(quick: bool = False) -> list[str]:
    sweep = SWEEP_QUICK if quick else SWEEP_FULL
    rows, records = build_scaling_rows(sweep, reps=1 if quick else 2)
    write_bench_json(records, quick=quick)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small slice, 1 rep (CI smoke lane); writes the "
                         "gitignored BENCH_build.quick.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=args.quick)


if __name__ == "__main__":
    main()
