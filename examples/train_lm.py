"""Train a small LM end-to-end on the synthetic pipeline with
checkpoint/restore — the training-substrate driver.

  PYTHONPATH=src python examples/train_lm.py [--arch granite_3_8b]
      [--steps 200] [--resume]
"""
import argparse
import dataclasses
import os
import time

import jax

from repro.configs import registry
from repro.train import checkpoint as ck
from repro.train import data as data_lib
from repro.train import fault_tolerance as ft
from repro.train import train_loop
from repro.train.optimizer import AdamWConfig

CKPT = os.environ.get("REPRO_CKPT_DIR", "/tmp/repro_train_lm")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=0,
                    help="0 = keep smoke default")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (FT demo)")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch).smoke()
    cfg = dataclasses.replace(
        cfg, vocab=512, d_model=args.d_model,
        n_layers=args.layers or cfg.n_layers)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"params~{cfg.total_params()/1e6:.1f}M")

    dcfg = data_lib.DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16,
                               seed=0)
    ds = data_lib.SyntheticLM(dcfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    scfg = train_loop.StepConfig(compute_dtype="float32", remat=False)
    state = train_loop.init_state(jax.random.PRNGKey(0), cfg, opt, scfg)
    step = jax.jit(train_loop.make_train_step(cfg, opt, scfg))

    if args.resume and ck.latest_step(CKPT) is not None:
        state, at = ck.restore(CKPT, state)
        print(f"resumed from step {at}")

    fails = {args.fail_at} if args.fail_at >= 0 else set()

    def injector(s):
        if s in fails:
            fails.discard(s)
            print(f"!! injected failure at step {s} — recovering")
            return True
        return False

    t0 = time.time()
    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % 20 == 0 or s == args.steps:
            rate = s / max(time.time() - t0, 1e-9)
            print(f"step {s:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  {rate:.1f} steps/s")

    state, steps, restarts = ft.run_resumable(
        state, step, lambda s: ds.global_batch(s), n_steps=args.steps,
        ckpt_dir=CKPT, ckpt_every=50, fail_injector=injector,
        on_metrics=on_metrics)
    floor = data_lib.optimal_loss(dcfg)
    print(f"\ndone: {steps} steps, {restarts} restarts, "
          f"final loss {losses[-1]:.4f} (source entropy {floor:.4f})")


if __name__ == "__main__":
    main()
