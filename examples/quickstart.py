"""Quickstart: build a proximity graph and run k-ANNS with the public API.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import eval as evallib
from repro.core import vamana
from repro.core.tuner import estimator


def main():
    # 1. a dataset (synthetic clustered vectors, Sift-like geometry)
    data, queries = estimator.make_dataset(n=3000, d=32, nq=100, seed=0)
    gt = evallib.ground_truth(data, queries, k=10)

    # 2. build one Vamana graph
    params = vamana.VamanaParams(L=48, M=16, alpha=1.2)
    res = vamana.build_vamana(data, params, batch_size=512)
    print(f"built vamana {params}: #dist={res.counters.total:,}")

    # 3. search and evaluate the QPS/recall frontier
    fn = evallib.flat_graph_search_fn(res.g, 0, data, res.entry, k=10)
    points = evallib.evaluate_search_fn(fn, queries, gt, 10,
                                        ef_grid=[10, 20, 40, 80])
    for p in points:
        print(f"  ef={p.ef:3d}  recall@10={p.recall:.3f}  "
              f"QPS={p.qps:,.0f}  #dist={p.n_dist:,}")

    # 4. the paper's trick: build THREE graphs at once, sharing work
    trio = [vamana.VamanaParams(L=40, M=12, alpha=1.1),
            vamana.VamanaParams(L=48, M=16, alpha=1.2),
            vamana.VamanaParams(L=56, M=16, alpha=1.3)]
    multi = vamana.build_multi_vamana(data, trio, batch_size=512)
    c = multi.counters
    print(f"\nmulti-build of 3 graphs: computed {c.total:,} distances "
          f"vs {c.total_base:,} for independent builds "
          f"({1 - c.total / c.total_base:.1%} saved by ESO+EPO)")

    # 5. the metric is first-class: the same pipeline serves cosine workloads
    #    (embedding search) — data is unit-normalized once at the boundary.
    gt_cos = evallib.ground_truth(data, queries, k=10, metric="cosine")
    res_cos = vamana.build_vamana(data, params, batch_size=512,
                                  metric="cosine")
    fn = evallib.flat_graph_search_fn(res_cos.g, 0, data, res_cos.entry,
                                      k=10, metric="cosine")
    rec = evallib.recall_at_k(fn(queries, 40).pool_ids[:, :10], gt_cos)
    print(f"\ncosine-metric vamana: recall@10={rec:.3f} at ef=40")


if __name__ == "__main__":
    main()
