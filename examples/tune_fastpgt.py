"""End-to-end driver (the paper's kind): tune PG construction parameters
with FastPGT and compare against VDTuner on the same budget.

  PYTHONPATH=src python examples/tune_fastpgt.py [--pg vamana] [--budget 12]
"""
import argparse

from repro.configs.paper_pg import CONFIG as PGW
from repro.core.tuner import estimator, fastpgt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pg", default=PGW.pg,
                    choices=["hnsw", "vamana", "nsg"])
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--metric", default="l2",
                    choices=["l2", "ip", "cosine"])
    ap.add_argument("--batch", type=int, default=6)
    ap.add_argument("--n", type=int, default=PGW.n // 2)
    args = ap.parse_args()

    data, queries = estimator.make_dataset(args.n, PGW.d, PGW.n_queries,
                                           seed=0)
    kw = dict(budget=args.budget, batch=args.batch, k=PGW.k, seed=0,
              scale=0.15, build_batch_size=512, ef_grid=[10, 20, 40],
              metric=args.metric)

    print(f"=== FastPGT tuning {args.pg} / {args.metric} "
          f"(budget {args.budget}, batch {args.batch}) ===")
    fast = fastpgt.tune(args.pg, data, queries, mode="fastpgt", **kw)
    print(fast.summary())

    print("\n=== VDTuner baseline (same budget) ===")
    slow = fastpgt.tune(args.pg, data, queries, mode="vdtuner", **kw)
    print(slow.summary())

    sp_t = slow.t_total / max(fast.t_total, 1e-9)
    sp_d = slow.counters.total / max(fast.counters.total, 1)
    print(f"\nFastPGT speedup: {sp_t:.2f}x wall, {sp_d:.2f}x fewer "
          f"build distances")
    print("\nbest configs on the Pareto front (qps, recall):")
    for q, r in fast.pareto_front():
        print(f"  qps={q:8.0f}  recall@{PGW.k}={r:.3f}")


if __name__ == "__main__":
    main()
