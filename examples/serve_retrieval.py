"""Serve a small model with batched requests + retrieval attention over a
PG-indexed KV cache — where FastPGT meets the LM stack (paper ref [8]).

Batched serving API: ``retrieval.retrieval_attention_batched(idx, q,
top_k=..., ef=...)`` blocks a large or ragged decode-query batch into
static bucketed shapes (padding rows are masked out of the search), so XLA
compiles one search per block shape and reuses it across requests — and
each query's visit state is an O(ef) hash set rather than an O(n_ctx)
bitmap (``visited_impl="hash"``, the serving default; DESIGN.md §9), so
the same code path serves million-key caches.  The demo below pushes all
16 decode queries through it in one call.

  PYTHONPATH=src python examples/serve_retrieval.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import vamana
from repro.core.tuner import estimator, fastpgt
from repro.models import model as M
from repro.serve import retrieval
from repro.serve.engine import Request, RetrievalKnobs, ServeEngine


def main():
    # ---- 1. batched serving of a small decoder-only model ----------------
    cfg = registry.get_config("granite_3_8b").smoke()
    cfg = dataclasses.replace(cfg, vocab=256, n_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=4, max_seq=96)
    reqs = [Request(rid=i, prompt=np.array([3 + i, 7, 11]), max_new=8)
            for i in range(8)]
    t0 = time.time()
    eng.run(reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests / {toks} tokens "
          f"in {time.time() - t0:.1f}s on {eng.b} slots")

    # ---- 2. retrieval attention over a long synthetic KV cache -----------
    r = np.random.default_rng(0)
    n_ctx, dh = 4000, 32
    keys = jnp.asarray(r.normal(size=(n_ctx, dh)), jnp.float32)
    values = jnp.asarray(r.normal(size=(n_ctx, dh)), jnp.float32)
    q = keys[r.integers(0, n_ctx, 16)] * 4.0      # concentrated attention

    # tune the index construction parameters with FastPGT (tiny budget).
    # Attention ranks keys by inner product, so tune and build under the
    # native "ip" metric — no normalize-and-L2 reduction anywhere.
    print("\ntuning the KV index with FastPGT (metric=ip) ...")
    res = fastpgt.tune(
        "vamana", keys, keys[:64], mode="fastpgt", budget=4, batch=2,
        seed=0, scale=0.2, build_batch_size=512, ef_grid=[16, 32],
        mc_samples=8, metric="ip")
    best = max(zip(res.cfgs, res.objectives), key=lambda t: t[1][1])
    print(f"best cfg: {best[0]} -> recall={best[1][1]:.3f}")

    bp = vamana.VamanaParams(L=best[0]["L"], M=best[0]["M"],
                             alpha=best[0]["alpha"])
    idx = retrieval.build_index(keys, values, bp, metric="ip")
    # serving knobs live in one place (hash visit state + width-W
    # multi-expansion are the defaults; see README's knob table)
    knobs = RetrievalKnobs(top_k=48, ef=96, block_size=8)
    approx, sr = retrieval.retrieval_attention_batched(
        idx, q, **knobs.batched_kwargs())
    exact = retrieval.exact_attention(keys, values, q)
    cos = jnp.sum(approx * exact, -1) / (
        jnp.linalg.norm(approx, axis=-1) * jnp.linalg.norm(exact, axis=-1))
    frac = int(sr.n_computed) / (q.shape[0] * n_ctx)
    print(f"retrieval attention: cosine(exact)={float(jnp.mean(cos)):.4f} "
          f"touching {frac:.1%} of the KV cache per query")

    # ---- 3. mesh-partitioned serving: shard the corpus across devices ----
    # build_index(num_shards=S) splits the keys into S subindexes; searches
    # scatter-gather over a "shard" mesh axis (DESIGN.md §11).  On one CPU
    # this runs a 1-way mesh; launch with
    #   XLA_FLAGS=--xla_force_host_platform_device_count=4
    # to see the same code distribute over 4 devices.
    n_dev = len(jax.devices())
    knobs4 = dataclasses.replace(knobs, num_shards=4)
    idx4 = retrieval.build_index(keys, values, bp, metric="ip",
                                 **knobs4.index_kwargs())
    approx4, sr4 = retrieval.retrieval_attention_batched(
        idx4, q, **knobs4.batched_kwargs())
    cos4 = jnp.sum(approx4 * exact, -1) / (
        jnp.linalg.norm(approx4, axis=-1) * jnp.linalg.norm(exact, axis=-1))
    print(f"sharded ({knobs4.num_shards} shards on {n_dev} device(s)): "
          f"cosine(exact)={float(jnp.mean(cos4)):.4f} "
          f"ndist={int(sr4.n_computed)} (psum over shards)")


if __name__ == "__main__":
    main()
