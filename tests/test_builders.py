"""PG builders: multi == single invariance (core paper claim: ESO/EPO are
pure optimizations), recall quality, and counter reductions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as evallib
from repro.core import hnsw, nsg, vamana


@pytest.fixture(scope="module")
def ds():
    r = np.random.default_rng(11)
    data = jnp.asarray(r.normal(size=(600, 12)), jnp.float32)
    queries = jnp.asarray(r.normal(size=(30, 12)), jnp.float32)
    gt = evallib.ground_truth(data, queries, 10)
    return data, queries, gt


def test_multi_equals_single_fast():
    """Small-n sharing-invariance guard that stays in the CI fast lane
    (the thorough variants below are slow-marked)."""
    r = np.random.default_rng(21)
    data = jnp.asarray(r.normal(size=(250, 8)), jnp.float32)
    ps = [vamana.VamanaParams(L=16, M=8, alpha=1.1),
          vamana.VamanaParams(L=20, M=8, alpha=1.2)]
    multi = vamana.build_multi_vamana(data, ps, seed=3, batch_size=128)
    for i, p in enumerate(ps):
        single = vamana.build_multi_vamana(data, [p], seed=3, batch_size=128,
                                           use_eso=False, use_epo=False)
        np.testing.assert_array_equal(
            np.asarray(multi.g.ids[i])[:, :p.M],
            np.asarray(single.g.ids[0])[:, :p.M])


@pytest.mark.slow
def test_multi_vamana_equals_singles(ds):
    """Graph i of a shared multi-build must be IDENTICAL to building
    parameter i alone — sharing must never change results."""
    data, _, _ = ds
    ps = [vamana.VamanaParams(L=24, M=10, alpha=1.1),
          vamana.VamanaParams(L=32, M=12, alpha=1.3)]
    multi = vamana.build_multi_vamana(data, ps, seed=5, batch_size=128)
    for i, p in enumerate(ps):
        single = vamana.build_multi_vamana(data, [p], seed=5, batch_size=128,
                                           use_eso=False, use_epo=False)
        np.testing.assert_array_equal(
            np.asarray(multi.g.ids[i])[:, :p.M],
            np.asarray(single.g.ids[0])[:, :p.M])


def test_multi_vamana_counter_savings(ds):
    data, _, _ = ds
    ps = [vamana.VamanaParams(L=24, M=10, alpha=1.1),
          vamana.VamanaParams(L=28, M=12, alpha=1.2),
          vamana.VamanaParams(L=32, M=12, alpha=1.3)]
    shared = vamana.build_multi_vamana(data, ps, seed=5, batch_size=128)
    assert shared.counters.search < shared.counters.search_base
    assert shared.counters.prune <= shared.counters.prune_base
    assert shared.counters.total < shared.counters.total_base


@pytest.mark.parametrize("builder,params,searcher", [
    ("vamana", vamana.VamanaParams(L=48, M=16, alpha=1.2), None),
    ("nsg", nsg.NSGParams(K=16, L=48, M=16), None),
    ("hnsw", hnsw.HNSWParams(efc=48, M=16), None),
])
def test_builder_recall(ds, builder, params, searcher):
    data, queries, gt = ds
    if builder == "vamana":
        res = vamana.build_multi_vamana(data, [params], batch_size=128)
        fn = evallib.flat_graph_search_fn(res.g, 0, data, res.entry, 10)
        got = fn(queries, 60).pool_ids[:, :10]
    elif builder == "nsg":
        res = nsg.build_multi_nsg(data, [params], batch_size=128)
        fn = evallib.flat_graph_search_fn(res.g, 0, data, res.entry, 10)
        got = fn(queries, 60).pool_ids[:, :10]
    else:
        res = hnsw.build_multi_hnsw(data, [params], batch_size=128)
        got = hnsw.hnsw_search(res.g, 0, data, queries, 10, 60).pool_ids
    rec = evallib.recall_at_k(got, gt)
    assert rec > 0.80, f"{builder} recall {rec}"


@pytest.mark.slow
def test_hnsw_shared_levels_and_multi(ds):
    data, queries, gt = ds
    ps = [hnsw.HNSWParams(efc=32, M=12), hnsw.HNSWParams(efc=48, M=16)]
    multi = hnsw.build_multi_hnsw(data, ps, seed=2, batch_size=128)
    assert multi.counters.search < multi.counters.search_base
    # levels identical across graphs by construction (deterministic random)
    for gi in range(2):
        got = hnsw.hnsw_search(multi.g, gi, data, queries, 10, 60).pool_ids
        assert evallib.recall_at_k(got, gt) > 0.75


def test_nsg_connectivity_repair(ds):
    data, queries, gt = ds
    res = nsg.build_multi_nsg(data, [nsg.NSGParams(K=12, L=32, M=10)],
                              batch_size=128)
    # after repair, searches from the medoid must reach >90% of gt space
    fn = evallib.flat_graph_search_fn(res.g, 0, data, res.entry, 10)
    rec = evallib.recall_at_k(fn(queries, 80).pool_ids[:, :10], gt)
    assert rec > 0.7


@pytest.mark.slow
def test_multi_vamana_equals_singles_cosine(ds):
    """Sharing invariance must hold under every metric, not just L2."""
    data, _, _ = ds
    ps = [vamana.VamanaParams(L=24, M=10, alpha=1.1),
          vamana.VamanaParams(L=32, M=12, alpha=1.3)]
    multi = vamana.build_multi_vamana(data, ps, seed=5, batch_size=128,
                                      metric="cosine")
    for i, p in enumerate(ps):
        single = vamana.build_multi_vamana(data, [p], seed=5, batch_size=128,
                                           use_eso=False, use_epo=False,
                                           metric="cosine")
        np.testing.assert_array_equal(
            np.asarray(multi.g.ids[i])[:, :p.M],
            np.asarray(single.g.ids[0])[:, :p.M])


@pytest.mark.parametrize("metric", ["cosine", "ip"])
def test_builder_recall_other_metrics(ds, metric):
    data, queries, _ = ds
    gt = evallib.ground_truth(data, queries, 10, metric=metric)
    res = vamana.build_multi_vamana(
        data, [vamana.VamanaParams(L=48, M=16, alpha=1.2)],
        batch_size=128, metric=metric)
    fn = evallib.flat_graph_search_fn(res.g, 0, data, res.entry, 10, metric)
    rec = evallib.recall_at_k(fn(queries, 60).pool_ids[:, :10], gt)
    floor = 0.9 if metric == "cosine" else 0.6   # raw MIPS graphs are hubby
    assert rec > floor, f"vamana/{metric} recall {rec}"


@pytest.mark.slow
def test_hnsw_cosine_reaches_recall_target(ds):
    """Acceptance: an HNSW built on a cosine-metric dataset reaches
    recall@10 >= 0.9 at some ef in the default eval grid [10, 20, 40, 80]."""
    data, queries, _ = ds
    gt = evallib.ground_truth(data, queries, 10, metric="cosine")
    res = hnsw.build_multi_hnsw(data, [hnsw.HNSWParams(efc=48, M=16)],
                                batch_size=128, metric="cosine")
    recs = []
    for ef in [10, 20, 40, 80]:
        got = hnsw.hnsw_search(res.g, 0, data, queries, 10, ef,
                               metric="cosine").pool_ids
        recs.append(evallib.recall_at_k(got, gt))
    assert max(recs) >= 0.9, f"cosine hnsw recall sweep {recs}"
