"""Streaming mutable index (serve/streaming.py, DESIGN.md §15).

Covers the streaming contract end to end: pristine bit-identity with the
wrapped static index, a sustained insert+delete+search workload whose
recall@10 never drops more than 0.02 below the static baseline while
deleted ids never appear in any pool, the ef−k tombstone refill, WAL
crash-consistency (a simulated kill at EVERY byte offset of the log
recovers exactly the acked mutation prefix; a torn final record is
refused, not half-applied), the FaultPlan ``crash`` action + disk
recovery round trip, affected-shard-only compaction, pointer-last
generation commit, and hot-swap into a running ResilientSearcher.
"""
import os
import shutil
import struct

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import vamana
from repro.core.graph import INVALID
from repro.serve import engine as engine_lib
from repro.serve import resilience, retrieval, streaming
from repro.train import checkpoint as ckpt_lib

S = 4
TOP_K = 8
EF = 24
PARAMS = vamana.VamanaParams(L=24, M=8, alpha=1.2)


def _blob_corpus(seed=0, n=400, d=8, blobs=4, n_extra=64, n_q=32):
    """Clustered corpus + a held-out pool of insertable vectors drawn from
    the same blobs (so inserts are realistic near-neighbors, not noise)."""
    r = np.random.default_rng(seed)
    centers = r.normal(size=(blobs, d)).astype(np.float32) * 8.0
    data = (centers[r.integers(0, blobs, n)]
            + r.normal(size=(n, d))).astype(np.float32)
    extra = (centers[r.integers(0, blobs, n_extra)]
             + r.normal(size=(n_extra, d))).astype(np.float32)
    queries = (centers[r.integers(0, blobs, n_q)]
               + r.normal(size=(n_q, d))).astype(np.float32)
    return data, extra, queries


def _build(data, num_shards=1, **kw):
    return retrieval.build_index(
        jnp.asarray(data), jnp.asarray(data), PARAMS, metric="l2",
        num_shards=num_shards, seed=3,
        **(dict(assign="kmeans") if num_shards > 1 else {}), **kw)


@pytest.fixture(scope="module")
def unsharded():
    data, extra, queries = _blob_corpus(seed=0)
    return _build(data), data, extra, queries


@pytest.fixture(scope="module")
def sharded():
    data, extra, queries = _blob_corpus(seed=1)
    return _build(data, num_shards=S), data, extra, queries


def _oracle_topk(vecs, ext_ids, queries, k):
    """Exact external-id top-k over the live corpus (rows of ``vecs``)."""
    d2 = ((vecs[None, :, :] - queries[:, None, :]) ** 2).sum(-1)
    order = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return np.asarray(ext_ids)[order]


def _recall(pool_ids, gt):
    hits = sum(len(set(pool_ids[q].tolist()) & set(gt[q].tolist()))
               for q in range(gt.shape[0]))
    return hits / gt.size


# ---------------------------------------------------------------------------
# Pristine serving and the mutation hot path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", ["unsharded", "sharded"])
def test_pristine_bit_identity(fixture, request):
    """Acceptance pin: an empty-delta, no-tombstone MutableIndex serves
    BIT-identical outputs and pools through the wrapped index's own
    cached batched programs."""
    idx, _, _, queries = request.getfixturevalue(fixture)
    mi = streaming.MutableIndex(idx)
    q = jnp.asarray(queries)
    out0, res0 = retrieval.retrieval_attention_batched(
        idx, q, top_k=TOP_K, ef=EF)
    out1, res1 = mi.attention_batched(q, top_k=TOP_K, ef=EF)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))
    np.testing.assert_array_equal(np.asarray(res0.pool_ids),
                                  np.asarray(res1.pool_ids))
    np.testing.assert_array_equal(np.asarray(res0.pool_dist),
                                  np.asarray(res1.pool_dist))
    assert int(res0.n_computed) == int(res1.n_computed)
    assert int(res0.hops) == int(res1.hops)


def test_insert_is_immediately_searchable(unsharded):
    idx, _, _, queries = unsharded
    mi = streaming.MutableIndex(idx)
    ext = mi.insert(queries[0])            # exact query match
    ids, dist = mi.knn(jnp.asarray(queries[:1]), TOP_K, EF)
    assert int(np.asarray(ids)[0, 0]) == ext
    assert float(np.asarray(dist)[0, 0]) == 0.0


def test_tombstone_refill_keeps_topk_full(unsharded):
    """Deleting current top-k members refills from the ef−k slack: the
    top-k stays all-valid, the deleted ids vanish, survivors keep their
    relative order."""
    idx, _, _, queries = unsharded
    mi = streaming.MutableIndex(idx)
    q = jnp.asarray(queries[:4])
    before = np.asarray(mi.knn(q, TOP_K, EF)[0])
    victims = {int(before[0, 0]), int(before[0, 2]), int(before[1, 1])}
    for v in victims:
        mi.delete(v)
    after = np.asarray(mi.knn(q, TOP_K, EF)[0])
    assert not (set(after.ravel().tolist()) & victims)
    assert (after != INVALID).all()        # ef−k slack refilled every slot
    survivors = [i for i in before[0].tolist() if i not in victims]
    assert after[0, :len(survivors)].tolist() == survivors


def test_delete_unknown_id_raises_before_logging(tmp_path, unsharded):
    idx, *_ = unsharded
    mi = streaming.MutableIndex.wrap(idx, wal_dir=str(tmp_path))
    mi.delete(5)
    wal = mi._wal_path()
    size = os.path.getsize(wal)
    with pytest.raises(KeyError, match="not live"):
        mi.delete(5)                        # already deleted
    with pytest.raises(KeyError, match="not live"):
        mi.delete(10_000)                   # never existed
    assert os.path.getsize(wal) == size     # nothing was logged


@pytest.mark.parametrize("fixture", ["unsharded", "sharded"])
def test_sustained_mutation_workload(fixture, request):
    """The acceptance workload: interleaved inserts, deletes, and searches.
    Throughout — including after a compaction — recall@10 stays within
    0.02 of the static baseline, deleted ids NEVER appear in any pool,
    and searches themselves never trigger compaction."""
    idx, data, extra, queries = request.getfixturevalue(fixture)
    mi = streaming.MutableIndex(
        idx, delta_capacity=len(extra) + 1, delta_graph_min=16)
    q = jnp.asarray(queries)
    k = 10
    gt0 = _oracle_topk(data, np.arange(len(data)), queries, k)
    static_recall = _recall(np.asarray(mi.knn(q, k, EF)[0]), gt0)

    live_vecs = {i: data[i] for i in range(len(data))}
    deleted: set[int] = set()
    r = np.random.default_rng(7)
    extra_iter = iter(extra)
    for round_i in range(6):
        for _ in range(8):                 # 8 inserts per round
            v = next(extra_iter)
            live_vecs[mi.insert(v)] = v
        for ext in r.choice(sorted(live_vecs), size=4, replace=False):
            mi.delete(int(ext))
            del live_vecs[int(ext)]
            deleted.add(int(ext))
        ids = np.asarray(mi.knn(q, k, EF)[0])
        assert not (set(ids.ravel().tolist()) & deleted), round_i
        exts = np.fromiter(live_vecs, np.int64)
        gt = _oracle_topk(np.stack([live_vecs[e] for e in exts]),
                          exts, queries, k)
        assert _recall(ids, gt) >= static_recall - 0.02, round_i
    assert mi.compactions == 0             # searches never compact
    mi.compact()
    assert mi.pristine
    ids = np.asarray(mi.knn(q, k, EF)[0])
    assert not (set(ids.ravel().tolist()) & deleted)
    exts = np.fromiter(live_vecs, np.int64)
    gt = _oracle_topk(np.stack([live_vecs[e] for e in exts]), exts,
                      queries, k)
    assert _recall(ids, gt) >= static_recall - 0.02


# ---------------------------------------------------------------------------
# WAL crash consistency.
# ---------------------------------------------------------------------------

def _mutate(mi, data, extra):
    """A fixed mutation script; returns the acked (op, ...) sequence."""
    acked = []
    for v in extra[:3]:
        acked.append(("insert", mi.insert(v)))
    mi.delete(5)
    acked.append(("delete", 5))
    acked.append(("delete", acked[1][1]))
    mi.delete(acked[1][1])
    return acked


def _live_state(mi):
    return (sorted(mi._loc), sorted(mi._tomb_ext), mi.delta_count,
            mi._next_seq)


def test_wal_kill_at_every_byte_offset(tmp_path, unsharded):
    """The crash-recovery acceptance gate: for EVERY byte offset t of the
    WAL, a process killed with only t bytes durable recovers to exactly
    the acked prefix — the mutations whose frames fit in [0, t] — and
    the torn tail is truncated away."""
    idx, data, extra, _ = unsharded
    wal_dir = str(tmp_path / "wal")
    mi = streaming.MutableIndex.wrap(idx, wal_dir=wal_dir)
    _mutate(mi, data, extra)
    wal = mi._wal_path()
    raw = open(wal, "rb").read()
    # frame boundaries: state after each complete record
    bodies, good = ckpt_lib.read_framed(wal)
    assert good == len(raw)                # our own writes are never torn
    ends = [0]
    off = 0
    for b in bodies:
        off += ckpt_lib._FRAME_HDR.size + len(b)
        ends.append(off)
    # reference states: replay 0..j records on a fresh wrap
    refs = []
    ref = streaming.MutableIndex.wrap(idx, wal_dir=str(tmp_path / "ref"))
    refs.append(_live_state(ref))
    for b in bodies:
        rec = streaming._decode(b)
        if rec[0] == "insert":
            ref._apply_insert(rec[2], rec[3], rec[4])
        else:
            ref._apply_delete(rec[2])
        ref._next_seq = rec[1] + 1
        refs.append(_live_state(ref))
    crash_dir = str(tmp_path / "crash")
    for t in range(len(raw) + 1):
        acked = sum(e <= t for e in ends) - 1   # complete frames in [0, t]
        shutil.rmtree(crash_dir, ignore_errors=True)
        shutil.copytree(wal_dir, crash_dir)
        cw = os.path.join(crash_dir, os.path.basename(wal))
        with open(cw, "rb+") as f:
            f.truncate(t)
        got = streaming.MutableIndex.load(crash_dir)
        assert _live_state(got) == refs[acked], f"offset {t}"
        assert os.path.getsize(cw) == ends[acked]   # torn tail truncated
    # the full-file case recovered everything
    assert refs[-1] == _live_state(streaming.MutableIndex.load(wal_dir))


def test_torn_final_record_refused_not_half_applied(tmp_path, unsharded):
    """A corrupted (not just short) final record must be refused whole:
    flipping one payload byte fails the crc, and recovery lands on the
    previous record's state."""
    idx, data, extra, _ = unsharded
    wal_dir = str(tmp_path / "wal")
    mi = streaming.MutableIndex.wrap(idx, wal_dir=wal_dir)
    acked = _mutate(mi, data, extra)
    wal = mi._wal_path()
    raw = bytearray(open(wal, "rb").read())
    raw[-1] ^= 0xFF                        # bit-rot inside the last body
    open(wal, "wb").write(raw)
    got = streaming.MutableIndex.load(wal_dir)
    last_op, last_ext = acked[-1]
    assert last_op == "delete"
    assert last_ext in got._loc            # the torn delete did NOT apply
    assert got._next_seq == mi._next_seq - 1
    # and the torn bytes are physically gone
    _, good = ckpt_lib.read_framed(wal)
    assert os.path.getsize(wal) == good < len(raw)


def test_wal_replay_rejects_wrong_sequence(tmp_path, unsharded):
    """A WAL that is not the committed generation's suffix (wrong seq
    numbering — e.g. an orphan from another generation) is refused."""
    idx, *_ = unsharded
    wal_dir = str(tmp_path / "wal")
    mi = streaming.MutableIndex.wrap(idx, wal_dir=wal_dir)
    mi.delete(0)
    # duplicate the record: second copy replays seq 1 again
    wal = mi._wal_path()
    raw = open(wal, "rb").read()
    open(wal, "ab").write(raw)
    with pytest.raises(ValueError, match="seq"):
        streaming.MutableIndex.load(wal_dir)


def test_crash_fault_recovers_from_disk(tmp_path, unsharded):
    """FaultPlan 'crash' end to end: the injected crash surfaces through
    ResilientSearcher WITHOUT retry (it is not a RuntimeError), and a
    fresh MutableIndex.load serves every acked mutation."""
    idx, data, extra, queries = unsharded
    wal_dir = str(tmp_path / "wal")
    mi = streaming.MutableIndex.wrap(idx, wal_dir=wal_dir)
    ext = mi.insert(extra[0])
    mi.delete(7)
    plan = resilience.FaultPlan(
        [resilience.Fault("crash", 0, at_call=1)])
    naps = []
    rs = resilience.ResilientSearcher(
        mi, engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF),
        plan=plan, clock=lambda: 0.0, sleep=naps.append)
    q = jnp.asarray(queries[:4])
    _, before = rs.search(q)               # call 0: serves fine
    with pytest.raises(resilience.InjectedCrash, match="recover from disk"):
        rs.search(q)
    assert not naps                        # no retry backoff: crash != retry
    recovered = streaming.MutableIndex.load(wal_dir)
    assert ext in recovered._loc and 7 in recovered._tomb_ext
    rs2 = resilience.ResilientSearcher(
        recovered, engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF),
        clock=lambda: 0.0, sleep=lambda s: None)
    _, after = rs2.search(q)
    np.testing.assert_array_equal(np.asarray(before.pool_ids),
                                  np.asarray(after.pool_ids))


# ---------------------------------------------------------------------------
# Compaction.
# ---------------------------------------------------------------------------

def test_compaction_rebuilds_only_affected_shards(sharded):
    """Sharded compaction: shards with no tombstoned member and no routed
    delta vector keep their adjacency bytes; only affected shards pass
    through the build hook."""
    idx, data, extra, queries = sharded
    built = []

    def counting_build(local):
        built.append(local.shape[0])
        return streaming.MutableIndex._default_build(mi, local)

    mi = streaming.MutableIndex(idx, build_fn=counting_build)
    # tombstone members of exactly one shard
    gids = np.asarray(idx.shards.global_ids)
    counts = np.asarray(idx.shards.counts)
    victims = gids[2, :3].tolist()
    for v in victims:
        mi.delete(int(v))
    old_ids = np.asarray(idx.shards.ids)
    mi.compact()
    assert len(built) == 1                 # only shard 2 rebuilt
    assert built[0] == counts[2] - 3
    new = mi.main.shards
    for s in (0, 1, 3):                    # untouched shards: bytes kept
        c = int(counts[s])
        np.testing.assert_array_equal(
            np.asarray(new.ids)[s, :c], old_ids[s, :c])
    # deleted ids gone from the corpus, survivors all present (global ids
    # are compacted rows; external ids live in main_ext)
    remaining = set(mi.main_ext.tolist())
    assert not (remaining & set(victims))
    assert len(remaining) == len(data) - 3
    rows = set(np.asarray(new.global_ids).ravel().tolist())
    rows.discard(INVALID)
    assert rows == set(range(len(data) - 3))   # every row owned once
    ids = np.asarray(mi.knn(jnp.asarray(queries), TOP_K, EF)[0])
    assert not (set(ids.ravel().tolist()) & set(victims))


def test_compaction_routes_delta_and_triggers(sharded):
    """maybe_compact fires on the tombstone-fraction threshold, folds
    delta vectors into their nearest-centroid shards, and the compacted
    index finds them."""
    idx, data, extra, queries = sharded
    mi = streaming.MutableIndex(idx, tombstone_compact_frac=0.02)
    exts = [mi.insert(v) for v in extra[:6]]
    assert mi.maybe_compact() is False     # below both thresholds
    n_del = int(np.ceil(0.02 * len(data)))
    for i in range(n_del):
        mi.delete(i)
    assert mi.maybe_compact() is True
    assert mi.pristine and mi.compactions == 1
    assert mi.n_main == len(data) - n_del + 6
    # inserted vectors are now main-graph residents, searchable exactly
    ids, dist = mi.knn(jnp.asarray(extra[:2]), TOP_K, EF)
    assert np.asarray(ids)[0, 0] == exts[0]
    assert np.asarray(ids)[1, 0] == exts[1]


def test_compaction_commits_pointer_last(tmp_path, unsharded):
    """Generation roll: after compact(), the pointer names g1, g1's files
    exist, g0's files are gone, and the WAL restarts empty."""
    idx, data, extra, _ = unsharded
    wal_dir = str(tmp_path / "wal")
    mi = streaming.MutableIndex.wrap(idx, wal_dir=wal_dir)
    mi.insert(extra[0])
    mi.delete(3)
    mi.compact()
    names = set(os.listdir(wal_dir))
    assert "index.stream.json" in names
    assert {"index-g1.snapshot.npz", "index-g1.snapshot.json",
            "index-g1.stream.npz"} <= names
    assert not any(n.startswith("index-g0") for n in names)
    assert not any(n.endswith(streaming.WAL_SUFFIX) for n in names)
    # a crash that wiped generation g1's pointer would have kept g0: here
    # the pointer committed, so load serves g1 with zero replay
    got = streaming.MutableIndex.load(wal_dir)
    assert got.gen == 1 and got.pristine and got.n_main == mi.n_main


def test_searcher_hot_swap_after_compaction(sharded):
    """compact(searcher=...) swaps the searcher onto the new generation:
    health resets, the governor rebuilds, and serving continues with the
    compacted corpus."""
    idx, data, extra, queries = sharded
    mi = streaming.MutableIndex(idx)
    rs = resilience.ResilientSearcher(
        mi, engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF, num_shards=S,
                                      assign="kmeans"),
        clock=lambda: 0.0, sleep=lambda s: None)
    q = jnp.asarray(queries)
    rs.search(q)
    rs.health.kill(1)
    mi.delete(0)
    mi.compact(searcher=rs)
    assert rs.index is mi and rs.health.n_live == S
    _, res = rs.search(q)
    assert 0 not in np.asarray(res.pool_ids)


def test_compaction_releases_old_generation_buffers(unsharded):
    """Regression (buffer pinning): compact() must drop every corpus-sized
    device mirror of the OLD generation — the post-compact index is
    pristine, so ``_sync_delta`` never runs again to replace them, and an
    uncleared mirror pins the retired keys/values/search arrays on device
    for the process lifetime."""
    import gc
    import weakref
    idx, data, extra, queries = unsharded
    mi = streaming.MutableIndex(idx)
    mi.insert(extra[0])
    mi.delete(5)
    q = jnp.asarray(queries)
    mi.attention_batched(q, top_k=TOP_K, ef=EF)     # builds the mirrors
    assert mi._cat_idx is not None
    refs = [weakref.ref(a) for a in
            (mi._cat_idx.keys, mi._cat_idx.values, mi._cat_ext_dev,
             mi._d_search_dev, mi._d_live_dev)]
    mi.compact()
    gc.collect()
    dead = [r() is None for r in refs]
    assert all(dead), f"old-generation device buffers still live: {dead}"
    assert (mi._cat_idx is None and mi._cat_ext_dev is None
            and mi._d_search_dev is None and mi._d_live_dev is None
            and mi._tomb_cache == (-1, None))
    # and the delta brute-scan program cache is bounded, not unbounded
    assert streaming._delta_brute_fn.cache_info().maxsize == 8
    # post-compact serving still works through the pristine fast path
    ids, _ = mi.knn(q, TOP_K, EF)
    assert 5 not in np.asarray(ids)


@pytest.mark.parametrize("num_shards", [1, S])
def test_compaction_preserves_quantization(num_shards):
    """A quantized MutableIndex must stay quantized across generations:
    compact() re-quantizes the folded corpus (DESIGN.md §16) instead of
    silently degrading the new main to fp32."""
    from repro.core import metric as metric_lib
    # seeds mirror the module fixtures: their blob draws are connected
    # under PARAMS (seed=2's blobs disconnect the fused Vamana build —
    # a corpus property, unrelated to quantization)
    data, extra, queries = _blob_corpus(seed=0 if num_shards == 1 else 1)
    idx = _build(data, num_shards=num_shards, quantize="sq8")
    mi = streaming.MutableIndex(idx)
    exts = [mi.insert(v) for v in extra[:4]]
    mi.delete(7)
    mi.compact()
    assert mi.main.quantize == "sq8"
    if num_shards == 1:
        assert mi.main.quant is not None
        want = metric_lib.quantize_sq8(mi.main.search_keys)
        np.testing.assert_array_equal(np.asarray(mi.main.quant.codes),
                                      np.asarray(want.codes))
        np.testing.assert_array_equal(np.asarray(mi.main.quant.scale),
                                      np.asarray(want.scale))
    else:
        assert mi.main.shards.qcodes is not None
        assert mi.main.shards.qcodes.dtype == jnp.int8
        # routed sharded search reaches the folded insert exactly (the
        # fp32 re-rank restores exact distances over the sq8 pool)
        ids, dist = mi.knn(jnp.asarray(extra[:1]), TOP_K, EF)
        assert int(np.asarray(ids)[0, 0]) == exts[0]
    # recall parity against an identically-compacted fp32 twin (the fused
    # rebuild is deterministic, so both generations share the graph and
    # only the corpus representation differs)
    mi32 = streaming.MutableIndex(_build(data, num_shards=num_shards))
    for v in extra[:4]:
        mi32.insert(v)
    mi32.delete(7)
    mi32.compact()
    q = jnp.asarray(queries)
    gt = _oracle_topk(np.asarray(mi.main.keys), mi.main_ext, queries, TOP_K)
    rec32 = _recall(np.asarray(mi32.knn(q, TOP_K, EF)[0]), gt)
    rec8 = _recall(np.asarray(mi.knn(q, TOP_K, EF)[0]), gt)
    assert rec8 >= rec32 - 0.02, (rec32, rec8)
    assert 7 not in np.asarray(mi.knn(q, TOP_K, EF)[0])


def test_quantized_delta_serving(unsharded):
    """Pre-compaction: quantized main + fp32 delta fold consistently —
    a fresh insert is immediately searchable at exact distance 0 while
    the main graph serves sq8-with-rerank pools."""
    data, extra, queries = _blob_corpus(seed=0)
    idx = _build(data, quantize="sq8")
    mi = streaming.MutableIndex(idx)
    ext = mi.insert(queries[0])
    ids, dist = mi.knn(jnp.asarray(queries[:1]), TOP_K, EF)
    assert int(np.asarray(ids)[0, 0]) == ext
    assert float(np.asarray(dist)[0, 0]) == 0.0
