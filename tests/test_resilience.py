"""Serving resilience (serve/resilience.py, DESIGN.md §14).

Covers the degraded-mode contract end to end: fault injection through
``FaultPlan``, the quantified degraded-recall bound when a shard dies on a
clustered corpus (both execution strategies), snapshot -> restore
bit-identity, and the latency governor's downshift / hysteresis-guarded
recovery under a synthetic slow-shard plan.  Time is injected everywhere
(fake clock + no-op sleep), so the governor tests are deterministic and
the suite never actually stalls.
"""
import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import graph, search, vamana
from repro.core.graph import INVALID
from repro.distributed import sharding as sharding_lib
from repro.serve import engine as engine_lib
from repro.serve import resilience, retrieval

S = 4
TOP_K = 8
EF = 24


def _clustered_corpus(seed=0, n=400, d=8, blobs=4):
    """Well-separated blobs: kmeans shards align with them, so routing is
    meaningful and per-shard ground-truth fractions are non-trivial."""
    r = np.random.default_rng(seed)
    centers = r.normal(size=(blobs, d)).astype(np.float32) * 8.0
    assign = r.integers(0, blobs, n)
    data = centers[assign] + r.normal(size=(n, d)).astype(np.float32)
    queries = centers[r.integers(0, blobs, 32)] + r.normal(
        size=(32, d)).astype(np.float32)
    return data.astype(np.float32), queries.astype(np.float32)


@pytest.fixture(scope="module")
def sharded_index():
    data, queries = _clustered_corpus()
    params = vamana.VamanaParams(L=24, M=8, alpha=1.2)
    idx = retrieval.build_index(
        jnp.asarray(data), jnp.asarray(data), params, metric="l2",
        num_shards=S, assign="kmeans", seed=3)
    gt = np.argsort(
        ((data[None, :, :] - queries[:, None, :]) ** 2).sum(-1),
        axis=1, kind="stable")[:, :TOP_K]
    return idx, data, queries, gt


def _recall(pool_ids, gt):
    hits = 0
    for qi in range(gt.shape[0]):
        hits += len(set(pool_ids[qi].tolist()) & set(gt[qi].tolist()))
    return hits / gt.size


def _dead_fraction(idx, gt, shard):
    """Fraction of ground-truth (query, neighbor) pairs on ``shard``."""
    members = set(np.asarray(idx.shards.global_ids[shard]).tolist())
    members.discard(INVALID)
    return sum(int(g in members) for g in gt.ravel()) / gt.size


# ---------------------------------------------------------------------------
# Shard health + fault injection.
# ---------------------------------------------------------------------------

def test_shard_health_lifecycle():
    h = resilience.ShardHealth.fresh(4)
    assert h.mask() is None and h.n_live == 4     # healthy: no-mask program
    h.kill(2)
    assert h.n_live == 3
    np.testing.assert_array_equal(h.mask(), [True, True, False, True])
    h.delay(1, 0.25)
    assert h.live_delay() == 0.25
    h.kill(1)                       # dead shards don't stall the merge
    assert h.live_delay() == 0.0
    h.revive(1)                     # revive clears the injected delay too
    assert h.live_delay() == 0.0 and h.n_live == 3


def test_fault_plan_fires_at_scheduled_calls(sharded_index):
    idx, _, queries, _ = sharded_index
    plan = resilience.FaultPlan([
        resilience.Fault("kill", 1, at_call=1),
        resilience.Fault("delay", 2, at_call=1, seconds=0.5),
        resilience.Fault("revive", 1, at_call=3),
    ])
    knobs = engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF, num_shards=S,
                                      assign="kmeans")
    slept = []
    rs = resilience.ResilientSearcher(
        idx, knobs, plan=plan, clock=lambda: 0.0, sleep=slept.append)
    rs.search(jnp.asarray(queries[:4]))
    assert rs.health.n_live == S                  # call 0: nothing fired
    rs.search(jnp.asarray(queries[:4]))
    assert not rs.health.alive[1] and rs.health.delays_s[2] == 0.5
    assert slept and slept[-1] == 0.5             # slow shard stalls the call
    rs.search(jnp.asarray(queries[:4]))
    rs.search(jnp.asarray(queries[:4]))
    assert rs.health.alive[1]                     # call 3: revived
    assert rs.calls == 4


def test_fault_bad_kind_and_shard_rejected():
    with pytest.raises(ValueError, match="kind"):
        resilience.Fault("explode", 0, at_call=0)
    h = resilience.ShardHealth.fresh(2)
    plan = resilience.FaultPlan([resilience.Fault("kill", 7, at_call=0)])
    with pytest.raises(ValueError, match="7"):
        plan.apply(0, h)


def test_corrupt_shard_keeps_search_alive(sharded_index):
    """Corruption scrambles navigability, never validity: the damaged
    index still searches (legal local ids, recomputed flat_ids) on both
    execution strategies."""
    idx, _, queries, _ = sharded_index
    bad = resilience.corrupt_shard(idx.shards, 0, rows=32, seed=5)
    assert not np.array_equal(np.asarray(bad.ids[0]),
                              np.asarray(idx.shards.ids[0]))
    # ids stay legal local ids of shard 0
    c0 = int(bad.counts[0])
    rows = np.asarray(bad.ids[0][:c0])
    assert ((rows == INVALID) | ((rows >= 0) & (rows < c0))).all()
    # flat_ids agree with the scrambled per-shard ids (block-diagonal;
    # shard 0 has offset 0, so flat == local where valid)
    n_s = bad.ids.shape[1]
    ids0 = np.asarray(bad.ids[0])
    np.testing.assert_array_equal(
        np.asarray(bad.flat_ids).reshape(S, n_s, -1)[0],
        np.where(ids0 >= 0, ids0, INVALID))
    for strategy in ({}, {"routed_shards": 2}):
        res = search.sharded_knn_search(
            graph.place_sharded(bad), jnp.asarray(queries[:4]), TOP_K, EF,
            metric="l2", visited_impl="hash", expand_width=2, **strategy)
        assert (np.asarray(res.pool_ids) != INVALID).any()


# ---------------------------------------------------------------------------
# Degraded-recall contract (the acceptance bound).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["scatter_gather", "routed"])
def test_degraded_recall_bound_one_dead_shard(sharded_index, strategy):
    """Killing 1 of 4 shards loses at most that shard's ground-truth
    member fraction + 0.01 recall — on BOTH execution strategies, with
    counters excluding the dead shard's work (DESIGN.md §14)."""
    idx, _, queries, gt = sharded_index
    kw = dict(metric="l2", visited_impl="hash", expand_width=2)
    if strategy == "routed":
        kw["routed_shards"] = 2
    full = search.sharded_knn_search(idx.shards, jnp.asarray(queries),
                                     TOP_K, EF, **kw)
    recall_full = _recall(np.asarray(full.pool_ids), gt)
    for dead in range(S):
        mask = np.ones(S, bool)
        mask[dead] = False
        deg = search.sharded_knn_search(idx.shards, jnp.asarray(queries),
                                        TOP_K, EF, shard_mask=mask, **kw)
        pool = np.asarray(deg.pool_ids)
        # dead shard's members never appear in any pool
        members = set(np.asarray(idx.shards.global_ids[dead]).tolist())
        members.discard(INVALID)
        leaked = set(pool.ravel().tolist()) & members
        assert not leaked, f"dead shard {dead} leaked ids {leaked}"
        # every query still gets a full pool of valid ids
        assert (pool != INVALID).all()
        # counters exclude the dead shard.  Scatter-gather simply drops
        # its work, so totals strictly shrink; the router instead
        # re-ranks queries onto other live shards, so totals may shift
        # either way — there the no-leak check above is the contract.
        if strategy == "scatter_gather":
            assert int(deg.n_computed) < int(full.n_computed)
        # the quantified bound
        bound = recall_full - _dead_fraction(idx, gt, dead) - 0.01
        recall_deg = _recall(pool, gt)
        assert recall_deg >= bound, (
            f"strategy={strategy} dead={dead}: recall {recall_deg:.4f} "
            f"< bound {bound:.4f} (full {recall_full:.4f}, dead_frac "
            f"{_dead_fraction(idx, gt, dead):.4f})")


def test_all_dead_raises_and_mask_validated(sharded_index):
    idx, _, queries, _ = sharded_index
    q = jnp.asarray(queries[:2])
    with pytest.raises(ValueError, match="all-False"):
        search.sharded_knn_search(idx.shards, q, TOP_K, EF,
                                  shard_mask=np.zeros(S, bool))
    with pytest.raises(ValueError, match="dtype"):
        search.sharded_knn_search(idx.shards, q, TOP_K, EF,
                                  shard_mask=np.ones(S, np.int32))
    with pytest.raises(ValueError, match="shape"):
        search.sharded_knn_search(idx.shards, q, TOP_K, EF,
                                  shard_mask=np.ones(S + 1, bool))
    # unsharded index + mask is a usage error, not a silent no-op
    r = np.random.default_rng(0)
    keys = r.normal(size=(64, 8)).astype(np.float32)
    flat = retrieval.build_index(
        jnp.asarray(keys), jnp.asarray(keys),
        vamana.VamanaParams(L=16, M=6, alpha=1.2), metric="l2")
    with pytest.raises(ValueError, match="unsharded"):
        retrieval.retrieval_attention(flat, q, top_k=4, ef=8,
                                      shard_mask=np.array([True]))


def test_healthy_mask_is_bit_identical(sharded_index):
    """shard_mask=None and an all-True mask both produce the PR 7 healthy
    results exactly (the existing parity pins stay pinned)."""
    idx, _, queries, _ = sharded_index
    q = jnp.asarray(queries)
    for kw in ({}, {"routed_shards": 2}):
        a = search.sharded_knn_search(idx.shards, q, TOP_K, EF,
                                      metric="l2", **kw)
        b = search.sharded_knn_search(idx.shards, q, TOP_K, EF,
                                      metric="l2",
                                      shard_mask=np.ones(S, bool), **kw)
        np.testing.assert_array_equal(np.asarray(a.pool_ids),
                                      np.asarray(b.pool_ids))
        np.testing.assert_array_equal(np.asarray(a.pool_dist),
                                      np.asarray(b.pool_dist))
        assert int(a.n_computed) == int(b.n_computed)


# ---------------------------------------------------------------------------
# Snapshot / restore.
# ---------------------------------------------------------------------------

def test_snapshot_restore_bit_identical_sharded(sharded_index, tmp_path):
    idx, _, queries, _ = sharded_index
    man = resilience.save_index(idx, str(tmp_path), tag="t")
    assert man.endswith(resilience.SNAPSHOT_MANIFEST)
    idx2 = resilience.load_index(str(tmp_path), tag="t")
    assert idx2.num_shards == S and idx2.metric == idx.metric
    assert idx2.provenance == idx.provenance
    q = jnp.asarray(queries)
    for kw in ({}, {"routed_shards": 2}):
        a, ra = retrieval.retrieval_attention_batched(idx, q, top_k=TOP_K,
                                                      ef=EF, **kw)
        b, rb = retrieval.retrieval_attention_batched(idx2, q, top_k=TOP_K,
                                                      ef=EF, **kw)
        np.testing.assert_array_equal(np.asarray(ra.pool_ids),
                                      np.asarray(rb.pool_ids))
        np.testing.assert_array_equal(np.asarray(ra.pool_dist),
                                      np.asarray(rb.pool_dist))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_snapshot_restore_unsharded(tmp_path):
    r = np.random.default_rng(1)
    keys = r.normal(size=(96, 8)).astype(np.float32)
    idx = retrieval.build_index(
        jnp.asarray(keys), jnp.asarray(keys),
        vamana.VamanaParams(L=16, M=6, alpha=1.2), metric="ip")
    resilience.save_index(idx, str(tmp_path))
    idx2 = resilience.load_index(str(tmp_path))
    q = jnp.asarray(r.normal(size=(5, 8)).astype(np.float32))
    _, ra = retrieval.retrieval_attention(idx, q, top_k=4, ef=8)
    _, rb = retrieval.retrieval_attention(idx2, q, top_k=4, ef=8)
    np.testing.assert_array_equal(np.asarray(ra.pool_ids),
                                  np.asarray(rb.pool_ids))


@pytest.mark.parametrize("num_shards", [1, S])
def test_snapshot_roundtrips_quantization(num_shards, tmp_path):
    """Quantization state survives save/load bit-identically: codes,
    scale, and dequantized norms are RESTORED from the archive (never
    recomputed — a re-quantize at load time could round differently),
    the manifest records the scheme, and the restored index serves
    identical quantized pools."""
    import json
    from repro.core import metric as metric_lib
    data, queries = _clustered_corpus(seed=7)
    idx = retrieval.build_index(
        jnp.asarray(data), jnp.asarray(data),
        vamana.VamanaParams(L=16, M=6, alpha=1.2), metric="l2",
        num_shards=num_shards, quantize="sq8",
        **(dict(assign="kmeans", seed=3) if num_shards > 1 else {}))
    man = resilience.save_index(idx, str(tmp_path), tag="q")
    with open(man) as f:
        manifest = json.load(f)
    assert manifest["quantize"] == "sq8"
    assert manifest["quantization"]["scheme"] == "sq8-symmetric-per-dim"
    assert manifest["quantization"]["zero_point"] == 0
    idx2 = resilience.load_index(str(tmp_path), tag="q")
    assert idx2.quantize == "sq8"
    if num_shards == 1:
        for a, b in zip(idx.quant, idx2.quant):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert idx2.quant.codes.dtype == jnp.int8
    else:
        for name in ("qcodes", "qscale", "qnorms"):
            np.testing.assert_array_equal(
                np.asarray(getattr(idx.shards, name)),
                np.asarray(getattr(idx2.shards, name)))
        assert idx2.shards.qcodes.dtype == jnp.int8
    q = jnp.asarray(queries)
    _, ra = retrieval.retrieval_attention_batched(idx, q, top_k=TOP_K,
                                                  ef=EF)
    _, rb = retrieval.retrieval_attention_batched(idx2, q, top_k=TOP_K,
                                                  ef=EF)
    np.testing.assert_array_equal(np.asarray(ra.pool_ids),
                                  np.asarray(rb.pool_ids))
    np.testing.assert_array_equal(np.asarray(ra.pool_dist),
                                  np.asarray(rb.pool_dist))


def test_snapshot_fp32_manifest_has_no_quantization(sharded_index,
                                                    tmp_path):
    """fp32 snapshots record quantize="none" / null scheme, and older
    archives without the field load as fp32 (manifest default)."""
    import json
    idx, *_ = sharded_index
    man = resilience.save_index(idx, str(tmp_path), tag="f")
    with open(man) as f:
        manifest = json.load(f)
    assert manifest["quantize"] == "none"
    assert manifest["quantization"] is None
    idx2 = resilience.load_index(str(tmp_path), tag="f")
    assert idx2.quantize == "none" and idx2.quant is None


def test_torn_snapshot_refused(sharded_index, tmp_path):
    """npz without manifest == a writer died mid-snapshot: load refuses
    with a diagnostic instead of restoring an unverifiable archive."""
    idx, *_ = sharded_index
    man = resilience.save_index(idx, str(tmp_path), tag="torn")
    os.unlink(man)
    with pytest.raises(FileNotFoundError, match="mid-snapshot"):
        resilience.load_index(str(tmp_path), tag="torn")
    with pytest.raises(FileNotFoundError):
        resilience.load_index(str(tmp_path), tag="never_written")


def test_snapshot_overwrite_is_atomic(sharded_index, tmp_path):
    """Re-snapshotting the same tag replaces both files; a reader between
    the two writes still sees a complete (old) snapshot, never a torn
    mix — guaranteed by write-temp-then-rename + manifest-written-last."""
    idx, _, queries, _ = sharded_index
    resilience.save_index(idx, str(tmp_path), tag="t")
    resilience.save_index(idx, str(tmp_path), tag="t")   # overwrite path
    idx2 = resilience.load_index(str(tmp_path), tag="t")
    q = jnp.asarray(queries[:4])
    _, ra = retrieval.retrieval_attention_batched(idx, q, top_k=TOP_K,
                                                  ef=EF)
    _, rb = retrieval.retrieval_attention_batched(idx2, q, top_k=TOP_K,
                                                  ef=EF)
    np.testing.assert_array_equal(np.asarray(ra.pool_ids),
                                  np.asarray(rb.pool_ids))
    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert not leftovers, leftovers


# ---------------------------------------------------------------------------
# Deadline governor: ladder, downshift, hysteresis, retry.
# ---------------------------------------------------------------------------

def test_degradation_ladder_order():
    knobs = engine_lib.RetrievalKnobs(top_k=8, ef=32, expand_width=4,
                                      num_shards=4, assign="kmeans",
                                      routed_shards=4, deadline_ms=50.0)
    ladder = resilience.degradation_ladder(knobs)
    assert ladder[0] == knobs                       # rung 0 = healthy knobs
    efs = [k.ef for k in ladder]
    assert efs[0] == 32 and min(efs) == 8           # ef halves, floors top_k
    assert efs == sorted(efs, reverse=True)
    # ef sheds first, then routing, then width (recall-cheapest first)
    first_p = next(i for i, k in enumerate(ladder) if k.routed_shards == 1)
    first_w = next(i for i, k in enumerate(ladder) if k.expand_width == 1)
    assert efs.index(8) <= first_p <= first_w
    # every rung is a valid knob set (frozen dataclass revalidates)
    for k in ladder:
        assert k.top_k <= k.ef


def test_governor_inert_without_budget():
    gov = resilience.LatencyGovernor(
        engine_lib.RetrievalKnobs(top_k=8, ef=32))
    for _ in range(10):
        gov.observe(100.0)
    assert gov.level == 0 and gov.knobs.ef == 32


def test_governor_downshift_and_hysteresis_recovery():
    knobs = engine_lib.RetrievalKnobs(top_k=8, ef=32, expand_width=1,
                                      deadline_ms=100.0)
    gov = resilience.LatencyGovernor(knobs, alpha=1.0, patience=3)
    assert len(gov.ladder) == 3                     # ef 32 -> 16 -> 8 only
    # overload: one rung per over-budget tick, immediately
    gov.observe(0.2)
    assert gov.level == 1 and gov.knobs.ef == 16
    gov.observe(0.2)
    assert gov.level == 2 and gov.knobs.ef == 8
    gov.observe(0.2)
    assert gov.level == len(gov.ladder) - 1         # pinned at the bottom
    # dead band (between recover_frac*budget and budget): level frozen
    for _ in range(10):
        gov.observe(0.08)
    assert gov.level == len(gov.ladder) - 1
    # recovery needs `patience` consecutive calm ticks
    gov.observe(0.01)
    gov.observe(0.01)
    assert gov.level == len(gov.ladder) - 1         # 2 < patience: no move
    gov.observe(0.01)
    assert gov.level == len(gov.ladder) - 2         # 3rd calm tick: one rung
    gov.observe(0.08)                               # dead band resets calm
    gov.observe(0.01)
    gov.observe(0.01)
    assert gov.level == len(gov.ladder) - 2
    gov.observe(0.01)
    assert gov.level == len(gov.ladder) - 3


def test_governor_under_synthetic_slow_shard_plan(sharded_index):
    """End-to-end: a slow-shard fault pushes observed latency over budget
    -> the searcher downshifts; the shard recovers -> knobs climb back
    with hysteresis.  Clock and sleep are injected, so observed latency
    IS the injected stall and the test is deterministic."""
    idx, _, queries, _ = sharded_index
    knobs = engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF, num_shards=S,
                                      assign="kmeans", deadline_ms=100.0)
    plan = resilience.FaultPlan([
        resilience.Fault("delay", 2, at_call=0, seconds=0.5),
        resilience.Fault("delay", 2, at_call=4, seconds=0.0),   # recovers
    ])
    rs = resilience.ResilientSearcher(
        idx, knobs, plan=plan, clock=lambda: 0.0, sleep=lambda s: None,
        alpha=1.0, patience=2)
    q = jnp.asarray(queries[:4])
    assert rs.knobs.ef == EF
    for call in range(4):                           # overloaded ticks
        rs.search(q)
    assert rs.governor.level > 0
    assert rs.knobs.ef < EF                         # downshifted for real
    peak = rs.governor.level
    for call in range(2 * peak + 2):                # calm ticks
        rs.search(q)
    assert rs.governor.level == 0                   # full recovery
    assert rs.knobs == knobs


def test_search_with_retry_bounded():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient dispatch failure")
        return "ok"

    naps = []
    assert resilience.search_with_retry(
        flaky, retries=2, backoff_s=0.01, sleep=naps.append) == "ok"
    assert len(calls) == 3
    assert naps == [0.01, 0.02]                     # exponential backoff

    def always_fails():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError, match="permanent"):
        resilience.search_with_retry(always_fails, retries=1,
                                     sleep=lambda s: None)

    def validation_error():
        raise ValueError("bad arg")

    boom = []
    with pytest.raises(ValueError):                 # never retried
        resilience.search_with_retry(
            validation_error, retries=5, sleep=boom.append)
    assert not boom


def test_searcher_hot_swap(sharded_index, tmp_path):
    """swap_index mid-serving: a restored snapshot serves bit-identical
    pools through the same searcher, and health resets to all-alive."""
    idx, _, queries, _ = sharded_index
    knobs = engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF, num_shards=S,
                                      assign="kmeans")
    rs = resilience.ResilientSearcher(idx, knobs, clock=lambda: 0.0,
                                      sleep=lambda s: None)
    q = jnp.asarray(queries[:8])
    _, before = rs.search(q)
    rs.health.kill(1)
    resilience.save_index(idx, str(tmp_path), tag="swap")
    rs.swap_index(resilience.load_index(str(tmp_path), tag="swap"))
    assert rs.health.n_live == S                    # mask reset on swap
    _, after = rs.search(q)
    np.testing.assert_array_equal(np.asarray(before.pool_ids),
                                  np.asarray(after.pool_ids))


def test_searcher_rejects_mismatched_health(sharded_index):
    idx, *_ = sharded_index
    with pytest.raises(ValueError, match="shards"):
        resilience.ResilientSearcher(
            idx, engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF),
            health=resilience.ShardHealth.fresh(S + 1))


def test_swap_resets_governor_to_base_knobs(sharded_index):
    """Regression pin: swap_index must REBUILD the governor, not carry it
    over — a rung and EWMA measured against the old index would serve the
    new one with stale degraded knobs.  A slow-shard plan drives the
    governor down; the swap restores rung 0, clears the EWMA, and keeps
    the injected governor kwargs so later downshifts behave identically.
    """
    idx, _, queries, _ = sharded_index
    knobs = engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF, num_shards=S,
                                      assign="kmeans", deadline_ms=100.0)
    plan = resilience.FaultPlan(
        [resilience.Fault("delay", 2, at_call=0, seconds=0.5)])
    rs = resilience.ResilientSearcher(
        idx, knobs, plan=plan, clock=lambda: 0.0, sleep=lambda s: None,
        alpha=1.0, patience=2)
    q = jnp.asarray(queries[:4])
    for _ in range(3):
        rs.search(q)
    assert rs.governor.level > 0 and rs.knobs.ef < EF   # degraded for real
    assert rs.governor.ewma_s is not None
    rs.swap_index(idx)
    assert rs.governor.level == 0                  # fresh rung
    assert rs.governor.ewma_s is None              # old latencies forgotten
    assert rs.knobs == knobs                       # base knobs restored
    assert rs.governor.alpha == 1.0                # injected kwargs kept
    assert rs.governor.patience == 2


def test_swap_revalidates_base_knobs_on_shard_count_change(sharded_index):
    """Swapping to an index with a different shard count re-validates the
    base knobs (num_shards follows the index, routed_shards clamps) —
    otherwise the rebuilt ladder would issue searches the new index
    rejects."""
    idx, data, queries, _ = sharded_index
    knobs = engine_lib.RetrievalKnobs(top_k=TOP_K, ef=EF, num_shards=S,
                                      assign="kmeans", routed_shards=S,
                                      deadline_ms=100.0)
    rs = resilience.ResilientSearcher(idx, knobs, clock=lambda: 0.0,
                                      sleep=lambda s: None)
    params = vamana.VamanaParams(L=24, M=8, alpha=1.2)
    idx2 = retrieval.build_index(
        jnp.asarray(data), jnp.asarray(data), params, metric="l2",
        num_shards=2, assign="kmeans", seed=3)
    rs.swap_index(idx2)
    assert rs.governor.base.num_shards == 2
    assert rs.governor.base.routed_shards == 2     # clamped from S
    assert rs.health.num_shards == 2
    _, res = rs.search(jnp.asarray(queries[:4]))   # ladder actually serves
    assert res.pool_ids.shape == (4, TOP_K)
