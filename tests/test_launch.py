"""Launch layer: roofline math, registry cell rules, dry-run artifacts."""
import glob
import json
import os

import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch import roofline

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def test_model_flops_conventions():
    # train: 6*N*D; prefill: 2*N*D; decode: 2*N per token
    cfg = registry.get_config("granite_3_8b")
    n = cfg.total_params()
    t4 = SHAPES["train_4k"]
    assert roofline.model_flops("granite_3_8b", "train_4k") == pytest.approx(
        6.0 * n * t4.global_batch * t4.seq_len)
    p32 = SHAPES["prefill_32k"]
    assert roofline.model_flops("granite_3_8b", "prefill_32k") == \
        pytest.approx(2.0 * n * p32.global_batch * p32.seq_len)
    d32 = SHAPES["decode_32k"]
    assert roofline.model_flops("granite_3_8b", "decode_32k") == \
        pytest.approx(2.0 * n * d32.global_batch)


def test_moe_uses_active_params():
    dense = roofline.model_flops("yi_34b", "train_4k")
    moe = roofline.model_flops("arctic_480b", "train_4k")
    # arctic has 14x yi's total params but fewer ACTIVE params than yi
    assert moe < dense


def test_analyze_cell_terms():
    rec = {
        "status": "ok", "arch": "granite_3_8b", "shape": "train_4k",
        "mesh": "single", "chips": 256,
        "hlo": {"flops_per_chip": 3.94e14, "out_bytes_per_chip": 8.19e11,
                "collective_bytes_effective": 5e10, "collective_bytes": {},
                "trip_counts": {}},
        "memory": {"argument_bytes": 0, "peak_bytes_per_device": 1e9},
        "cost_analysis": {},
    }
    row = roofline.analyze_cell(rec)
    assert row["t_compute_s"] == pytest.approx(2.0)
    assert row["t_memory_s"] == pytest.approx(1.0)
    assert row["t_collective_s"] == pytest.approx(1.0)
    assert row["dominant"] in ("compute", "memory", "collective")
    assert 0 < row["roofline_fraction"] <= 1.0


def test_skip_rules_match_spec():
    """long_500k skips exactly the pure-full-attention + enc-dec archs."""
    expect_skip = {"whisper_small", "granite_3_8b", "yi_34b", "arctic_480b",
                   "grok_1_314b", "llava_next_34b"}
    got_skip = set()
    for arch in registry.ARCH_IDS:
        ok, _ = registry.cell_is_runnable(
            registry.get_config(arch), SHAPES["long_500k"])
        if not ok:
            got_skip.add(arch)
    assert got_skip == expect_skip
    assert len(registry.runnable_cells()) == 40 - len(expect_skip)


def test_input_specs_cover_modalities():
    import jax.numpy as jnp
    w = registry.input_specs(registry.get_config("whisper_small"),
                             SHAPES["train_4k"])
    assert "enc_input" in w and w["tokens"].dtype == jnp.int32
    v = registry.input_specs(registry.get_config("llava_next_34b"),
                             SHAPES["prefill_32k"])
    assert "patches" in v
    d = registry.input_specs(registry.get_config("granite_3_8b"),
                             SHAPES["decode_32k"])
    assert set(d) == {"token", "pos"}
    assert d["token"].shape == (128, 1)


@pytest.mark.skipif(not glob.glob(os.path.join(RESULTS, "*.json")),
                    reason="dry-run artifacts not generated")
def test_dryrun_artifacts_schema_and_health():
    """Every generated cell is ok/skipped (never error) and ok cells carry
    the roofline inputs."""
    cells = glob.glob(os.path.join(RESULTS, "*.json"))
    assert len(cells) == 80          # 10 archs x 4 shapes x 2 meshes
    n_ok = n_skip = 0
    for path in cells:
        with open(path) as f:
            rec = json.load(f)
        assert rec["status"] in ("ok", "skipped"), (path, rec.get("error"))
        if rec["status"] == "ok":
            n_ok += 1
            assert rec["hlo"]["flops_per_chip"] > 0
            assert rec["memory"]["peak_bytes_per_device"] > 0
            assert rec["chips"] in (256, 512)
            row = roofline.analyze_cell(rec)
            assert row["dominant"] in ("compute", "memory", "collective")
        else:
            n_skip += 1
    assert n_ok == 68 and n_skip == 12


def test_mesh_constructors_importable():
    """Importing mesh.py must not touch device state; constructors are
    functions (spec requirement)."""
    from repro.launch import mesh
    assert callable(mesh.make_production_mesh)
    assert callable(mesh.make_debug_mesh)
    # NOTE: make_production_mesh() itself needs 512 devices -> only the
    # dry-run process (with forced host devices) may call it.
