"""Differential search oracle: a pure-NumPy width-W best-first beam search
that defines the semantics ``search.beam_search`` must reproduce —
pools (ids AND order, i.e. visit/tie order), #dist counters, and hop
counts, not just recall.

The oracle mirrors the lockstep schedule exactly for the single-graph
external-query path (``knn_search``): per hop it expands the W closest
unexpanded pool entries, dedups candidates by first flat occurrence,
filters visited/query ids, and merges with the stable pool-first tie rule
(pool entries outrank equal-distance candidates; tied candidates keep
flat adjacency order).  Property tests drive it under hypothesis across
metric × visited_impl × expand_width on small random graphs;
``hypothesis`` is optional (PR 1 contract): without it each @given test
degrades to one deterministic example and the suite still collects.

Sensitivity is pinned, not assumed: ``test_oracle_catches_tie_rule_flip``
seeds the mutation the suite must catch (flipping ``_merge_topk``'s
pool-wins tie rule) and asserts the parity check fails on it.

The same contract covers query routing (DESIGN.md §13): ``oracle_route`` +
``oracle_routed_search`` define centroid scoring, stable top-p selection
(ties -> lower shard id), serial per-shard search and the ascending-shard
pool fold in pure NumPy; parity tests drive them against
``search.sharded_knn_search(routed_shards=p)`` across metric × impl × W × p,
and ``test_oracle_catches_router_flip`` proves the suite fails when the
router's tie rule is flipped to prefer the higher shard id.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import graph, search
from repro.core.graph import INVALID, random_knng_ids

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

    def settings(**kw):
        return lambda f: f

    class _Just:
        """Degraded @given: call the test once with each strategy's example."""
        def __init__(self, example):
            self.example = example

    class st:   # noqa: N801 - mirrors the hypothesis namespace
        @staticmethod
        def integers(lo, hi):
            return _Just((lo + hi) // 2)

        @staticmethod
        def sampled_from(options):
            return _Just(options[len(options) // 2])

    def given(**kwstrats):
        import functools
        import inspect

        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kw):
                kw.update({k: s.example for k, s in kwstrats.items()})
                return f(*args, **kw)
            # hide the strategy-provided params from pytest's fixture
            # resolution (hypothesis does the same)
            sig = inspect.signature(f)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in kwstrats])
            return wrapper
        return deco

METRICS = ["l2", "ip", "cosine"]
IMPLS = ["dense", "hash"]

# hypothesis draws static search shapes from these fixed sets so the jit
# cache is shared across examples (each distinct (ef, W, degree) is one
# beam_search compile; interpret-mode compiles are the CI-lane cost)
EF_W = [(8, 1), (16, 3), (8, 5)]
DEGREES = [4, 8]
N, B = 64, 8


def _np_dist(q, x, kernel):
    """kernels/ref.py gather_distance_ref numerics, in float32 numpy:
    l2 = max(sum((x-q)^2), 0); ip = 1 - <q, x>."""
    q = q.astype(np.float32)
    x = x.astype(np.float32)
    if kernel == "ip":
        return np.float32(1.0) - np.sum(q * x, dtype=np.float32)
    d = x - q
    return np.maximum(np.sum(d * d, dtype=np.float32), np.float32(0.0))


def oracle_search(adj, data, q, ef, entry, *, metric="l2", expand_width=1,
                  max_hops=None):
    """Width-W best-first beam search over one graph, one external query.

    Returns (pool_ids int32[ef], pool_dist f32[ef], n_dist, hops) with the
    INVALID/inf padding layout of ``knn_search`` pools.
    """
    adj = np.asarray(adj)
    data = np.asarray(data, np.float32)
    q = np.asarray(q, np.float32)
    if metric == "cosine":
        data = data / np.maximum(
            np.linalg.norm(data, axis=-1, keepdims=True), 1e-12)
        q = q / np.maximum(np.linalg.norm(q), 1e-12)
        kernel = "ip"
    else:
        kernel = metric
    ef = int(ef)
    W = min(int(expand_width), ef)
    max_hops = max_hops or search.default_max_hops(ef, expand_width)

    # pool: list of [dist, id, expanded], kept sorted (stable), <= ef long
    pool = [[_np_dist(q, data[entry], kernel), int(entry), False]]
    visited = {int(entry)}
    n_dist = 1
    hops = 0
    while hops < max_hops:
        sel = [e for e in pool if not e[2]][:W]
        if not sel:
            break
        cands = []
        seen_this_hop = set()
        for e in sel:
            e[2] = True
            for v in adj[e[1]]:
                v = int(v)
                if v == INVALID or v in seen_this_hop:
                    continue
                seen_this_hop.add(v)      # in-hop dup: count/insert once
                if v in visited:
                    continue
                visited.add(v)
                n_dist += 1
                cands.append([_np_dist(q, data[v], kernel), v, False])
        # stable merge, pool entries first (pool wins distance ties; tied
        # candidates keep flat adjacency order), truncate to ef
        pool = sorted(pool + cands, key=lambda e: e[0])[:ef]
        hops += 1
    ids = np.full(ef, INVALID, np.int32)
    dist = np.full(ef, np.inf, np.float32)
    for j, e in enumerate(pool):
        ids[j] = e[1]
        dist[j] = e[0]
    return ids, dist, n_dist, hops


def _case(seed, n, degree, quantize=False):
    r = np.random.default_rng(seed)
    data = r.normal(size=(n, 8)).astype(np.float32)
    if quantize:
        # integer coordinates -> exact float32 distances in BOTH numpy and
        # XLA regardless of reduction order, plus plenty of genuine ties
        data = np.round(data * 2.0)
    adj = np.asarray(random_knng_ids(seed, n, degree))
    # sprinkle INVALID padding mid-row (the search must skip, not misalign)
    mask = r.random(adj.shape) < 0.15
    adj = np.where(mask, INVALID, adj).astype(np.int32)
    queries = data[r.integers(0, n, B)] + r.normal(
        size=(B, data.shape[1])).astype(np.float32) * 0.25
    if quantize:
        queries = np.round(queries)
    return data, adj, queries.astype(np.float32)


def _assert_search_matches_oracle(data, adj, queries, ef, W, metric, impl,
                                  k=None):
    k = k or ef
    res = search.knn_search(jnp.asarray(adj), jnp.asarray(data),
                            jnp.asarray(queries), k, ef, 0, metric=metric,
                            visited_impl=impl, expand_width=W)
    got_ids = np.asarray(res.pool_ids)
    got_dist = np.asarray(res.pool_dist)
    total_dist = 0
    max_hops = 0
    for qi in range(queries.shape[0]):
        ids, dist, nd, hops = oracle_search(
            adj, data, queries[qi], ef, 0, metric=metric, expand_width=W)
        np.testing.assert_array_equal(
            got_ids[qi], ids[:k],
            err_msg=f"pool ids diverged from oracle (query {qi}, "
                    f"metric={metric}, impl={impl}, W={W})")
        np.testing.assert_allclose(got_dist[qi], dist[:k], rtol=1e-5,
                                   atol=1e-5)
        total_dist += nd
        max_hops = max(max_hops, hops)
    # dense counters are paper-exact; hash upper-bounds only on table
    # overflow, which auto-sizing precludes at these shapes (DESIGN.md §9)
    assert int(res.n_computed) == total_dist, (int(res.n_computed),
                                               total_dist)
    assert int(res.n_fresh) == total_dist
    assert int(res.hops) == max_hops, (int(res.hops), max_hops)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("metric", METRICS)
@settings(max_examples=8, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), ef_w=st.sampled_from(EF_W),
       degree=st.sampled_from(DEGREES))
def test_beam_search_matches_oracle(metric, impl, seed, ef_w, degree):
    ef, W = ef_w
    data, adj, queries = _case(seed, N, degree)
    _assert_search_matches_oracle(data, adj, queries, ef, W, metric, impl)


@pytest.mark.parametrize("impl", IMPLS)
@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), ef_w=st.sampled_from(EF_W))
def test_oracle_parity_under_exact_ties(impl, seed, ef_w):
    """Quantized integer coordinates: every distance is float32-exact in
    both implementations and heavily tied, so this pins the *order* the
    tie rules produce (pool-first, then flat candidate order), where
    continuous data would pin only the values."""
    ef, W = ef_w
    data, adj, queries = _case(seed, N, 8, quantize=True)
    _assert_search_matches_oracle(data, adj, queries, ef, W, "l2", impl)


def test_oracle_truncation_matches_k_prefix():
    """knn_search's k-prefix equals the oracle pool's k-prefix."""
    data, adj, queries = _case(3, N, 8)
    _assert_search_matches_oracle(data, adj, queries, 16, 2, "l2", "dense",
                                  k=5)


def flipped_tie_merge(pool_ids, pool_dist, expanded, cand_ids, cand_dist):
    """The seeded mutation: candidates win distance ties against pool
    entries (flips _merge_topk's `<` to `<=`)."""
    ef_max = pool_ids.shape[-1]
    kx = cand_ids.shape[-1]
    kc = min(kx, ef_max)
    order = jnp.argsort(cand_dist, axis=-1)[..., :kc]   # stable, == top_k tie
    c_dist = jnp.take_along_axis(cand_dist, order, axis=-1)
    c_ids = jnp.take_along_axis(cand_ids, order, axis=-1)
    cand_le = c_dist[..., None, :] <= pool_dist[..., :, None]
    rank_pool = jnp.arange(ef_max) + jnp.sum(cand_le, axis=-1)
    rr = jnp.arange(ef_max)
    i_r = jnp.sum(rank_pool[..., None, :] < rr[:, None], axis=-1)
    i_safe = jnp.minimum(i_r, ef_max - 1)
    is_pool = jnp.take_along_axis(rank_pool, i_safe, axis=-1) == rr
    j_safe = jnp.clip(rr - i_r, 0, kc - 1)
    out_ids = jnp.where(is_pool,
                        jnp.take_along_axis(pool_ids, i_safe, axis=-1),
                        jnp.take_along_axis(c_ids, j_safe, axis=-1))
    out_dist = jnp.where(is_pool,
                         jnp.take_along_axis(pool_dist, i_safe, axis=-1),
                         jnp.take_along_axis(c_dist, j_safe, axis=-1))
    out_exp = jnp.where(is_pool,
                        jnp.take_along_axis(expanded, i_safe, axis=-1),
                        False)
    return out_ids, out_dist, out_exp


def test_oracle_catches_tie_rule_flip():
    """Acceptance gate: the differential suite must FAIL on a seeded
    mutation of the merge tie rule.  Quantized data guarantees real
    distance ties, so the flipped rule must reorder some pool."""
    data, adj, queries = _case(7, N, 8, quantize=True)
    # sanity: the healthy merge passes on this exact workload
    _assert_search_matches_oracle(data, adj, queries, 16, 3, "l2", "dense")
    orig = search._merge_topk
    search._merge_topk = flipped_tie_merge
    search.beam_search.clear_cache()
    try:
        with pytest.raises(AssertionError, match="diverged from oracle"):
            _assert_search_matches_oracle(data, adj, queries, 16, 3, "l2",
                                          "dense")
    finally:
        search._merge_topk = orig
        search.beam_search.clear_cache()


# ---------------------------------------------------------------------------
# Query-routing oracle (DESIGN.md §13): centroid scoring + stable top-p
# selection + serial per-shard search + ascending-shard pool fold, all in
# pure NumPy — the semantics sharded_knn_search(routed_shards=p) must match.
# ---------------------------------------------------------------------------

S_ROUTE = 3          # shards in the routed parity cases
EF_W_P = [(8, 1, 1), (16, 3, 2), (8, 5, 2)]     # static (ef, W, p) shapes


def _np_route_scores(q, centroids, metric):
    """Per-shard centroid distances for one raw query, _np_dist numerics.

    Centroids already live in prepared space (graph.partition computes
    them over metric-prepared members), so only the query is prepared.
    """
    q = np.asarray(q, np.float32)
    if metric == "cosine":
        q = q / max(np.linalg.norm(q), 1e-12)
        kernel = "ip"
    else:
        kernel = metric
    return np.array([_np_dist(q, c, kernel) for c in centroids], np.float32)


def oracle_route(scores, p):
    """Stable top-p selection: equal centroid distances route to the LOWER
    shard id; selected ids come back ascending (the fold order)."""
    order = np.argsort(np.asarray(scores), kind="stable")
    return np.sort(order[:p])


def oracle_routed_search(sg_np, q, k, ef, p, *, metric="l2",
                         expand_width=1):
    """Routed search for one query over host-side ShardedGraph arrays.

    ``sg_np`` is a dict of np arrays (ids/data/global_ids/entries/
    centroids).  Scores centroids, selects top-p (oracle_route), runs
    ``oracle_search`` per routed shard in ascending order, restores global
    ids, and folds pools by the stable pool-first merge (earlier shards
    win distance ties — the serial-fold precedence the device path pins).
    Returns (ids int32[k], dist f32[k], n_dist, hops).
    """
    routed = oracle_route(
        _np_route_scores(q, sg_np["centroids"], metric), p)
    pool = []                              # [(dist, gid)] sorted, <= ef
    n_dist = 0
    hops = 0
    for s in routed:
        ids, dist, nd, hp = oracle_search(
            sg_np["ids"][s], sg_np["data"][s], q, ef,
            int(sg_np["entries"][s]), metric=metric,
            expand_width=expand_width)
        cands = [(float(dist[j]), int(sg_np["global_ids"][s][ids[j]]))
                 for j in range(ef) if ids[j] != INVALID]
        # stable sort of [pool, cands]: pool entries outrank equal-distance
        # candidates — exactly _merge_topk's rule (PR 5 pin)
        pool = sorted(pool + cands, key=lambda e: e[0])[:ef]
        n_dist += nd
        hops = max(hops, hp)
    out_ids = np.full(k, INVALID, np.int32)
    out_dist = np.full(k, np.inf, np.float32)
    for j, e in enumerate(pool[:k]):
        out_dist[j], out_ids[j] = e
    return out_ids, out_dist, n_dist, hops


def _routed_case(seed, n=90, degree=6):
    """A kmeans-partitioned corpus + queries, device- and host-side."""
    r = np.random.default_rng(seed)
    data = jnp.asarray(r.normal(size=(n, 8)), jnp.float32)
    queries = np.asarray(data)[r.integers(0, n, B)] + r.normal(
        size=(B, 8)).astype(np.float32) * 0.25
    sg = graph.partition(data, S_ROUTE, assignment="kmeans", seed=seed,
                         degree=degree)
    sg_np = {f: np.asarray(getattr(sg, f)) for f in
             ("ids", "data", "global_ids", "entries", "centroids")}
    return sg, sg_np, queries.astype(np.float32)


def _assert_routed_matches_oracle(sg, sg_np, queries, k, ef, W, p, metric,
                                  impl):
    res = search.sharded_knn_search(
        sg, jnp.asarray(queries), k, ef, metric=metric, visited_impl=impl,
        expand_width=W, routed_shards=p)
    got_ids = np.asarray(res.pool_ids)
    got_dist = np.asarray(res.pool_dist)
    total_dist = 0
    max_hops = 0
    for qi in range(queries.shape[0]):
        ids, dist, nd, hops = oracle_routed_search(
            sg_np, queries[qi], k, ef, p, metric=metric, expand_width=W)
        np.testing.assert_array_equal(
            got_ids[qi], ids,
            err_msg=f"routed pool diverged from routing oracle (query {qi}, "
                    f"metric={metric}, impl={impl}, W={W}, p={p})")
        np.testing.assert_allclose(got_dist[qi], dist, rtol=1e-5, atol=1e-5)
        total_dist += nd
        max_hops = max(max_hops, hops)
    # counters total the routed work only: psum over the per-shard blocks,
    # where un-routed (query, shard) pairs never appear (DESIGN.md §13)
    assert int(res.n_computed) == total_dist, (int(res.n_computed),
                                               total_dist)
    assert int(res.n_fresh) == total_dist
    assert int(res.hops) == max_hops, (int(res.hops), max_hops)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("metric", METRICS)
@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), ef_w_p=st.sampled_from(EF_W_P))
def test_routed_search_matches_oracle(metric, impl, seed, ef_w_p):
    ef, W, p = ef_w_p
    sg, sg_np, queries = _routed_case(seed)
    _assert_routed_matches_oracle(sg, sg_np, queries, ef, ef, W, p, metric,
                                  impl)


def test_routed_truncation_matches_k_prefix():
    """The k-prefix of the routed fold equals the oracle's k-prefix."""
    sg, sg_np, queries = _routed_case(11)
    _assert_routed_matches_oracle(sg, sg_np, queries, 5, 16, 2, 2, "l2",
                                  "dense")


# ---------------------------------------------------------------------------
# Masked-routing oracle (DESIGN.md §14): dead shards are excluded from BOTH
# routing (centroid scores -> +inf) and the merge (their pools never enter
# the fold), and counters total live-shard work only — the semantics
# sharded_knn_search(shard_mask=...) must match on every execution strategy.
# ---------------------------------------------------------------------------

def oracle_masked_search(sg_np, q, k, ef, *, shard_mask, p=None,
                         metric="l2", expand_width=1):
    """Degraded-mode reference for one query: search live shards only.

    ``p=None`` is masked scatter-gather: serial fold over the live shards
    in ascending id order.  ``p`` set is masked routing: dead shards score
    +inf (never selected while p <= live count; the caller-facing clamp is
    applied here too), then the usual stable top-p + ascending fold.
    Returns (ids int32[k], dist f32[k], n_dist, hops).
    """
    shard_mask = np.asarray(shard_mask, bool)
    live = np.flatnonzero(shard_mask)
    assert live.size, "oracle requires >= 1 live shard (the search raises)"
    if p is None:
        routed = live
    else:
        scores = _np_route_scores(q, sg_np["centroids"], metric)
        scores = np.where(shard_mask, scores, np.inf)
        routed = oracle_route(scores, min(int(p), live.size))
    pool = []
    n_dist = 0
    hops = 0
    for s in routed:
        ids, dist, nd, hp = oracle_search(
            sg_np["ids"][s], sg_np["data"][s], q, ef,
            int(sg_np["entries"][s]), metric=metric,
            expand_width=expand_width)
        cands = [(float(dist[j]), int(sg_np["global_ids"][s][ids[j]]))
                 for j in range(ef) if ids[j] != INVALID]
        pool = sorted(pool + cands, key=lambda e: e[0])[:ef]
        n_dist += nd
        hops = max(hops, hp)
    out_ids = np.full(k, INVALID, np.int32)
    out_dist = np.full(k, np.inf, np.float32)
    for j, e in enumerate(pool[:k]):
        out_dist[j], out_ids[j] = e
    return out_ids, out_dist, n_dist, hops


def _assert_masked_matches_oracle(sg, sg_np, queries, k, ef, W, p, mask,
                                  metric, impl, mesh=None):
    res = search.sharded_knn_search(
        sg, jnp.asarray(queries), k, ef, metric=metric, visited_impl=impl,
        expand_width=W, routed_shards=p, shard_mask=mask, mesh=mesh)
    got_ids = np.asarray(res.pool_ids)
    got_dist = np.asarray(res.pool_dist)
    total_dist = 0
    max_hops = 0
    for qi in range(queries.shape[0]):
        ids, dist, nd, hops = oracle_masked_search(
            sg_np, queries[qi], k, ef, shard_mask=mask, p=p, metric=metric,
            expand_width=W)
        np.testing.assert_array_equal(
            got_ids[qi], ids,
            err_msg=f"masked pool diverged from masked oracle (query {qi}, "
                    f"metric={metric}, impl={impl}, W={W}, p={p}, "
                    f"mask={mask.tolist()})")
        np.testing.assert_allclose(got_dist[qi], dist, rtol=1e-5, atol=1e-5)
        total_dist += nd
        max_hops = max(max_hops, hops)
    # the §14 counter contract: totals count LIVE-shard work only (a dead
    # shard's all-False row mask does zero work before the psum)
    assert int(res.n_computed) == total_dist, (int(res.n_computed),
                                               total_dist)
    assert int(res.n_fresh) == total_dist
    assert int(res.hops) == max_hops, (int(res.hops), max_hops)


MASKS = [np.array(m) for m in
         ([True, False, True], [False, True, True], [True, True, False])]


@pytest.mark.parametrize("impl", IMPLS)
@settings(max_examples=4, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), mask_i=st.integers(0, 2))
def test_masked_scatter_gather_matches_oracle(impl, seed, mask_i):
    sg, sg_np, queries = _routed_case(seed)
    _assert_masked_matches_oracle(sg, sg_np, queries, 8, 8, 2, None,
                                  MASKS[mask_i], "l2", impl)


@pytest.mark.parametrize("metric", METRICS)
@settings(max_examples=4, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), mask_i=st.integers(0, 2))
def test_masked_routed_search_matches_oracle(metric, seed, mask_i):
    """Host-routed (mesh) strategy under a mask: dead shards excluded
    from routing and merge, counters live-only."""
    sg, sg_np, queries = _routed_case(seed)
    _assert_masked_matches_oracle(sg, sg_np, queries, 8, 8, 2, 1,
                                  MASKS[mask_i], metric, "dense")


def test_masked_fused_routed_matches_oracle():
    """The packed single-dispatch strategy (flat-graph, in-jit routing)
    under a mask must match the same masked oracle — forced by a 1-device
    mesh the way test_sharded_search pins the healthy fused path."""
    import jax as _jax
    from repro.distributed import sharding as sharding_lib
    sg, sg_np, queries = _routed_case(17)
    mesh = sharding_lib.search_mesh(S_ROUTE, devices=_jax.devices()[:1])
    _assert_masked_matches_oracle(sg, sg_np, queries, 8, 8, 2, 1,
                                  MASKS[0], "l2", "dense", mesh=mesh)


def test_masked_clamps_routed_shards_to_live():
    """p > live-shard count clamps (with a warning) to searching every
    live shard — same pools as the masked scatter-gather oracle."""
    sg, sg_np, queries = _routed_case(19)
    mask = MASKS[0]                                  # 2 live of 3
    search._CLAMP_WARNED_STATE = None    # warn-once: arm a fresh transition
    with pytest.warns(UserWarning, match="clamping"):
        res = search.sharded_knn_search(
            sg, jnp.asarray(queries), 8, 8, metric="l2",
            visited_impl="dense", expand_width=2, routed_shards=3,
            shard_mask=mask)
    for qi in range(queries.shape[0]):
        ids, dist, _, _ = oracle_masked_search(
            sg_np, queries[qi], 8, 8, shard_mask=mask, p=2, metric="l2",
            expand_width=2)
        np.testing.assert_array_equal(np.asarray(res.pool_ids)[qi], ids)


def leaky_shard_search_body(graph_ids, data, global_ids, entries,
                            shard_mask, queries, row_mask, **kw):
    """The seeded §14 mutation: a merge that LEAKS dead shards — the body
    ignores the liveness mask, so every shard's pool (and counters) enter
    the fold as if all shards were alive."""
    return search._shard_search_body_orig(
        graph_ids, data, global_ids, entries,
        jnp.ones_like(shard_mask), queries, row_mask, **kw)


def test_oracle_catches_dead_shard_leak():
    """Acceptance gate: the masked suite must FAIL on a merge that leaks a
    dead shard's pool.  Queries are drawn near corpus points, so the dead
    shard (kmeans: the one owning those points for some query) holds
    top-pool entries for at least one query — leaking it changes pools,
    and the counter contract breaks for every query."""
    sg, sg_np, queries = _routed_case(23)
    mask = MASKS[0]
    # sanity: the healthy masked path passes on this exact workload
    _assert_masked_matches_oracle(sg, sg_np, queries, 8, 8, 2, None, mask,
                                  "l2", "dense")
    search._shard_search_body_orig = search._shard_search_body
    search._shard_search_body = leaky_shard_search_body
    search._sharded_search_fn.cache_clear()
    try:
        with pytest.raises(AssertionError):
            _assert_masked_matches_oracle(sg, sg_np, queries, 8, 8, 2,
                                          None, mask, "l2", "dense")
    finally:
        search._shard_search_body = search._shard_search_body_orig
        del search._shard_search_body_orig
        search._sharded_search_fn.cache_clear()


def flipped_route_topk(scores, p):
    """The seeded router mutation: equal centroid distances route to the
    HIGHER shard id (stable argsort over the column-reversed scores,
    mapped back) — rank order on distinct scores is unchanged."""
    S = scores.shape[-1]
    rev = jnp.argsort(scores[..., ::-1], axis=-1)[..., :p]
    return jnp.sort((S - 1 - rev).astype(jnp.int32), axis=-1)


def test_oracle_catches_router_flip():
    """Acceptance gate: the routed suite must FAIL when the router's
    top-p tie rule is flipped.  Duplicated centroid rows guarantee exact
    score ties on every query, so lower-id and higher-id routing pick
    different shards whenever the tied pair ranks first."""
    sg, sg_np, queries = _routed_case(13)
    # force exact centroid-score ties: shards 0 and 1 share a centroid row
    # (identical float rows -> identical scores), contents still differ
    cents = np.asarray(sg.centroids).copy()
    cents[1] = cents[0]
    sg.centroids = jnp.asarray(cents)
    sg_np["centroids"] = cents
    # sanity: the healthy router passes on this exact tied workload
    _assert_routed_matches_oracle(sg, sg_np, queries, 8, 8, 1, 1, "l2",
                                  "dense")
    orig = search.route_topk
    search.route_topk = flipped_route_topk
    try:
        # no jit-cache clearing needed: routing runs host-side and eagerly
        # resolves the module global on every call (DESIGN.md §13)
        with pytest.raises(AssertionError,
                           match="diverged from routing oracle"):
            _assert_routed_matches_oracle(sg, sg_np, queries, 8, 8, 1, 1,
                                          "l2", "dense")
    finally:
        search.route_topk = orig


# ---------------------------------------------------------------------------
# Streaming delta merge + tombstones (DESIGN.md §15): a pure-NumPy
# reference for the MutableIndex merged pool — main-graph beam pool,
# tombstone masking, brute delta candidates, and the pool-first tie-rule
# fold — including the #dist counter contract (main beam distances plus
# one brute distance per live delta slot per real query row).
# ---------------------------------------------------------------------------

def oracle_tombstone_mask(ids, dist, tomb_rows):
    """Pure-NumPy ``search.apply_tombstones``: survivors keep their order,
    tombstoned slots become INVALID/inf and sink behind every survivor."""
    out_ids = np.full(len(ids), INVALID, np.int32)
    out_dist = np.full(len(ids), np.inf, np.float32)
    j = 0
    for i, dv in zip(ids, dist):
        if int(i) != INVALID and int(i) not in tomb_rows:
            out_ids[j] = i
            out_dist[j] = dv
            j += 1
    return out_ids, out_dist


def oracle_streaming_search(adj, data, delta, delta_live, tomb_rows, q,
                            top_k, ef, *, metric="l2", expand_width=1):
    """The §15 merged pool for one query: (ids[top_k], dist[top_k],
    n_dist).  Main-graph rows keep their ids; delta slot s surfaces as
    ``len(data) + s``.  Mirrors MutableIndex exactly: full ef-wide main
    pool -> tombstone mask -> brute live-delta candidates (ties prefer the
    lower slot) folded pool-first -> THEN the top_k truncation, so the
    ef − top_k slack refills what tombstones evicted."""
    n = len(data)
    kernel = "ip" if metric == "cosine" else metric
    ids, dist, nd, _ = oracle_search(adj, data, q, ef, 0, metric=metric,
                                     expand_width=expand_width)
    ids, dist = oracle_tombstone_mask(ids, dist, tomb_rows)
    pool = [(float(dv), int(i)) for i, dv in zip(ids, dist)
            if int(i) != INVALID]
    cands = sorted(
        (float(_np_dist(q, delta[s], kernel)), n + s)
        for s in range(len(delta)) if delta_live[s])[:min(len(delta), ef)]
    merged = sorted([(d, 0, j, i) for j, (d, i) in enumerate(pool)]
                    + [(d, 1, j, i) for j, (d, i) in enumerate(cands)])[:ef]
    out_ids = np.full(ef, INVALID, np.int32)
    out_dist = np.full(ef, np.inf, np.float32)
    for j, (d, _, _, i) in enumerate(merged):
        out_ids[j] = i
        out_dist[j] = d
    return out_ids[:top_k], out_dist[:top_k], nd + sum(delta_live)


def _streaming_case(seed, n=60, degree=6):
    """Quantized integer coordinates (exact f32 distances under any
    reduction order, plenty of genuine ties) + a mutation script: 4 delta
    inserts (one then deleted) and 3 main tombstones drawn from live query
    pools so masking provably changes answers."""
    from repro.core import vamana as vamana_lib
    from repro.serve import retrieval, streaming

    r = np.random.default_rng(seed)
    data = np.round(r.normal(size=(n, 8)) * 2.0).astype(np.float32)
    adj = np.asarray(random_knng_ids(seed, n, degree))
    queries = np.round(data[r.integers(0, n, 8)] + r.normal(
        size=(8, data.shape[1])) * 2.0).astype(np.float32)
    idx = retrieval.RetrievalIndex(
        graph_ids=jnp.asarray(adj), keys=jnp.asarray(data),
        values=jnp.asarray(data), search_keys=jnp.asarray(data),
        entry=0, params=vamana_lib.VamanaParams(L=16, M=6, alpha=1.2),
        metric="l2")
    mi = streaming.MutableIndex(idx, delta_capacity=8,
                                delta_graph_min=10 ** 9)   # brute-only
    delta = np.round(r.normal(size=(4, 8)) * 2.0).astype(np.float32)
    exts = [mi.insert(v) for v in delta]
    mi.delete(exts[1])                       # dead delta slot
    # tombstone three DISTINCT rows currently surfacing in query pools
    pools = np.asarray(mi.knn(jnp.asarray(queries), 8, 16,
                              visited_impl="dense")[0])
    victims = []
    for qi in range(pools.shape[0]):
        for i in pools[qi]:
            if 0 <= int(i) < n and int(i) not in victims:
                victims.append(int(i))
                break
        if len(victims) == 3:
            break
    for v in victims:
        mi.delete(v)
    delta_live = [True, False, True, True] + [False] * 4
    return mi, data, adj, np.concatenate(
        [delta, np.zeros((4, 8), np.float32)]), delta_live, victims, queries


def _assert_streaming_matches_oracle(mi, data, adj, delta, delta_live,
                                     victims, queries, top_k, ef, W):
    _, res = mi.attention_batched(
        jnp.asarray(queries), top_k=top_k, ef=ef, visited_impl="dense",
        expand_width=W)
    got_ids = np.asarray(res.pool_ids)
    got_dist = np.asarray(res.pool_dist)
    total = 0
    for qi in range(queries.shape[0]):
        ids, dist, nd = oracle_streaming_search(
            adj, data, delta, delta_live, set(victims), queries[qi],
            top_k, ef, expand_width=W)
        np.testing.assert_array_equal(
            got_ids[qi], ids,
            err_msg=f"pool ids diverged from streaming oracle (query {qi},"
                    f" W={W})")
        np.testing.assert_allclose(got_dist[qi], dist, rtol=1e-5,
                                   atol=1e-5)
        total += nd
    assert int(res.n_computed) == total, (int(res.n_computed), total)
    assert int(res.n_fresh) == total


@pytest.mark.parametrize("seed", [0, 7])
@pytest.mark.parametrize("ef_w", [(16, 1), (24, 4)])
def test_streaming_search_matches_oracle(seed, ef_w):
    ef, W = ef_w
    case = _streaming_case(seed)
    _assert_streaming_matches_oracle(*case, 8, ef, W)


def leaky_apply_tombstones(pool_ids, pool_dist, tomb_ids):
    """The seeded §15 mutation: a mask that LEAKS — deleted ids stay in
    the pool as if never tombstoned."""
    return pool_ids, pool_dist


def test_oracle_catches_tombstone_leak():
    """Acceptance gate: the streaming suite must FAIL on a tombstone mask
    that leaks deleted ids.  The case tombstones rows drawn from live
    query pools, so leaking them provably changes at least one pool."""
    case = _streaming_case(11)
    # sanity: the healthy mask passes on this exact workload
    _assert_streaming_matches_oracle(*case, 8, 16, 1)
    orig = search.apply_tombstones
    search.apply_tombstones = leaky_apply_tombstones
    try:
        # no jit-cache clearing needed: knn_search applies the mask
        # eagerly and resolves the module global on every call
        with pytest.raises(AssertionError):
            _assert_streaming_matches_oracle(*case, 8, 16, 1)
    finally:
        search.apply_tombstones = orig


# ---------------------------------------------------------------------------
# Tombstone-mask properties (DESIGN.md §15): ``search.apply_tombstones``
# against ``oracle_tombstone_mask`` on the degenerate shapes the streaming
# refill tests never reach — duplicate tombstone ids, pools where every
# entry dies, and tombstone lists longer than the pool itself.
# ---------------------------------------------------------------------------

def _random_pools(r, b, ef, n):
    """Sorted INVALID-padded pools (the apply_tombstones input contract)."""
    ids = np.full((b, ef), INVALID, np.int32)
    dist = np.full((b, ef), np.inf, np.float32)
    for q in range(b):
        m = int(r.integers(1, ef + 1))
        ids[q, :m] = r.choice(n, size=m, replace=False)
        dist[q, :m] = np.sort(r.random(m).astype(np.float32))
    return ids, dist


def _assert_tombstones_match_oracle(pool_ids, pool_dist, tomb):
    """Row-wise parity, ids bit-equal and distances exact (masking moves
    values, never recomputes them)."""
    tomb = np.asarray(tomb, np.int32)
    got_i, got_d = search.apply_tombstones(
        jnp.asarray(pool_ids), jnp.asarray(pool_dist), jnp.asarray(tomb))
    dead = set(int(t) for t in tomb if int(t) != INVALID)
    for q in range(pool_ids.shape[0]):
        ids, dist = oracle_tombstone_mask(pool_ids[q], pool_dist[q], dead)
        np.testing.assert_array_equal(
            np.asarray(got_i)[q], ids,
            err_msg=f"tombstone mask diverged from oracle (query {q}, "
                    f"T={tomb.size})")
        np.testing.assert_array_equal(np.asarray(got_d)[q], dist)
    return got_i


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), ef=st.sampled_from([4, 8, 16]))
def test_tombstones_duplicate_ids_match_oracle(seed, ef):
    """An id listed T times dies exactly once — duplicates must not shift
    survivors or resurrect padding."""
    r = np.random.default_rng(seed)
    ids, dist = _random_pools(r, 6, ef, 32)
    base = r.choice(32, size=5, replace=False).astype(np.int32)
    tomb = np.concatenate([base, base, base[:2]])
    _assert_tombstones_match_oracle(ids, dist, tomb)


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), ef=st.sampled_from([4, 8, 16]))
def test_tombstones_all_dead_pool(seed, ef):
    """Tombstoning every live pool entry empties the pool completely:
    all-INVALID ids, all-+inf distances (the retrieval layer's softmax
    guard relies on this exact padding)."""
    r = np.random.default_rng(seed)
    ids, dist = _random_pools(r, 4, ef, 32)
    tomb = np.unique(ids[ids != INVALID])
    got_i = _assert_tombstones_match_oracle(ids, dist, tomb)
    assert bool((np.asarray(got_i) == INVALID).all())


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), ef=st.sampled_from([4, 8]))
def test_tombstones_longer_than_ef(seed, ef):
    """A tombstone list longer than the pool (mass deletion between
    compactions) masks correctly — extra ids, absent from the pool, are
    inert, and INVALID padding in the list never matches pool padding."""
    r = np.random.default_rng(seed)
    ids, dist = _random_pools(r, 6, ef, 32)
    tomb = np.concatenate([
        r.choice(32, size=min(3, ef), replace=False).astype(np.int32),
        np.arange(32, 32 + 2 * ef, dtype=np.int32),       # out-of-pool ids
        np.full(ef, INVALID, np.int32)])                  # padding
    _assert_tombstones_match_oracle(ids, dist, tomb)


# ---------------------------------------------------------------------------
# Warn-once clamp (DESIGN.md §14): the routed_shards > live clamp warns on
# ShardHealth state *transitions*, not per call — a degraded serving loop
# re-enters sharded_knn_search every batch and must not flood logs.
# ---------------------------------------------------------------------------

def test_clamp_warns_once_per_degraded_state():
    """100 calls under one degraded state -> exactly one warning; a healthy
    routed call resets the transition so the next degradation warns again."""
    import warnings as warnings_lib
    sg, sg_np, queries = _routed_case(29)
    mask = MASKS[0]                                      # 2 live of 3
    q = jnp.asarray(queries)
    search._CLAMP_WARNED_STATE = None
    with warnings_lib.catch_warnings(record=True) as w:
        warnings_lib.simplefilter("always")
        for _ in range(100):
            search.sharded_knn_search(
                sg, q, 8, 8, metric="l2", visited_impl="dense",
                routed_shards=3, shard_mask=mask)
        assert sum("clamping" in str(x.message) for x in w) == 1
        # p <= live: no clamp, and the warned state re-arms
        search.sharded_knn_search(
            sg, q, 8, 8, metric="l2", visited_impl="dense",
            routed_shards=2, shard_mask=mask)
        search.sharded_knn_search(
            sg, q, 8, 8, metric="l2", visited_impl="dense",
            routed_shards=3, shard_mask=mask)
        assert sum("clamping" in str(x.message) for x in w) == 2


# ---------------------------------------------------------------------------
# SQ8 quantization oracle (DESIGN.md §16): pure-NumPy symmetric per-dim
# int8 quantization + dequantized-corpus distances — the semantics
# metric.quantize_sq8 / the quantized kernel forms must match — plus the
# end-to-end recall parity bound (sq8 within 0.02 of fp32 at k=10 on the
# clustered corpus, all three metrics).
# ---------------------------------------------------------------------------

def oracle_quantize_sq8(x):
    """Pure-NumPy ``metric.quantize_sq8``: per-dim scale max|x[:,d]|/127
    (all-zero dims -> 1), codes clip(round(x/scale), ±127), norms over the
    DEQUANTIZED rows."""
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=0)
    scale = np.where(amax > 0, amax / np.float32(127.0),
                     np.float32(1.0)).astype(np.float32)
    codes = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    deq = codes.astype(np.float32) * scale
    norms = np.sum(deq * deq, axis=-1, dtype=np.float32)
    return codes, scale, norms


def test_quantize_sq8_matches_numpy_oracle():
    """codes and scale bit-identical to the oracle (round-half-to-even,
    zero-dim guard included); norms match to fp32 reduction tolerance."""
    from repro.core import metric as metric_lib
    r = np.random.default_rng(0)
    x = r.normal(size=(128, 16)).astype(np.float32) * 3.0
    x[:, 5] = 0.0                                 # all-zero dim -> scale 1
    x[3, 7] = 0.5                                 # exercise ties-to-even
    q = metric_lib.quantize_sq8(jnp.asarray(x))
    codes, scale, norms = oracle_quantize_sq8(x)
    np.testing.assert_array_equal(np.asarray(q.codes), codes)
    np.testing.assert_array_equal(np.asarray(q.scale), scale)
    assert float(np.asarray(q.scale)[5]) == 1.0
    np.testing.assert_allclose(np.asarray(q.norms), norms, rtol=1e-6)


@pytest.mark.parametrize("kernel", ["l2", "ip"])
def test_sq8_ref_distances_match_numpy_oracle(kernel):
    """ref.py's quantized forms price distances to the DEQUANTIZED corpus:
    parity against direct NumPy distance over codes*scale."""
    from repro.kernels import ref
    r = np.random.default_rng(1)
    x = r.normal(size=(64, 8)).astype(np.float32) * 2.0
    qs = r.normal(size=(5, 8)).astype(np.float32)
    codes, scale, norms = oracle_quantize_sq8(x)
    deq = codes.astype(np.float32) * scale
    want = np.array([[_np_dist(qv, row, kernel) for row in deq]
                     for qv in qs], np.float32)
    got = np.asarray(ref.pairwise_distance_sq8_ref(
        jnp.asarray(qs), jnp.asarray(codes), jnp.asarray(scale),
        jnp.asarray(norms), kernel))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # gather form: each query prices 6 gathered rows, no cache hits
    gidx = r.integers(0, 64, size=(5, 6))
    ggot = np.asarray(ref.gather_distance_sq8_ref(
        jnp.asarray(qs), jnp.asarray(codes[gidx]), jnp.asarray(scale),
        jnp.asarray(norms[gidx]), jnp.full((5, 6), np.inf, np.float32),
        jnp.ones((5, 6), bool), kernel))
    gwant = np.take_along_axis(want, gidx, axis=1)
    np.testing.assert_allclose(ggot, gwant, rtol=1e-5, atol=1e-5)


def test_recall_at_k_ignores_invalid_padding():
    """INVALID-vs-INVALID is padding, not a hit (the pre-fix bug): each
    query normalizes by its own valid-gt count and all-padding gt rows
    contribute 0."""
    from repro.core import eval as evallib
    gt = jnp.asarray(np.array(
        [[0, 1, INVALID, INVALID], [INVALID] * 4], np.int32))
    found = jnp.asarray(np.array(
        [[0, 5, INVALID, INVALID], [INVALID] * 4], np.int32))
    # row 0: gt {0, 1}, found hits {0} -> 1/2; row 1: no valid gt -> 0
    assert evallib.recall_at_k(found, gt) == pytest.approx(0.25)
    full = jnp.asarray(np.array([[0, 1, 2, 3], [4, 5, 6, 7]], np.int32))
    assert evallib.recall_at_k(full, full) == 1.0


@pytest.mark.parametrize("metric", METRICS)
def test_sq8_recall_parity_clustered(metric):
    """The tentpole bound: sq8 search + fp32 re-rank loses at most 0.02
    recall@10 vs the fp32 path on the clustered 10k corpus, per metric.
    Same graph, same ef — only the corpus representation differs."""
    from repro.core import eval as evallib
    from repro.core import metric as metric_lib
    from repro.core.tuner import estimator
    n, k, ef = 10_000, 10, 48
    data, queries = estimator.make_dataset(n, 16, 64, seed=5)
    gids = graph.random_knng_ids(5, n, 12)
    gt = evallib.ground_truth(data, queries, k, metric=metric)
    base = search.knn_search(gids, data, queries, k, ef, 0, metric=metric)
    quant = metric_lib.resolve(metric).prepare_quantized(data)
    q8 = search.knn_search(gids, data, queries, k, ef, 0, metric=metric,
                           quantize="sq8", quant=quant)
    rec_fp32 = evallib.recall_at_k(base.pool_ids, gt)
    rec_sq8 = evallib.recall_at_k(q8.pool_ids, gt)
    assert rec_sq8 >= rec_fp32 - 0.02, (metric, rec_fp32, rec_sq8)
    # the fp32 re-rank is COUNTED work: one distance per valid pool entry
    # on top of the quantized beam (counter semantics, DESIGN.md §16)
    assert int(q8.n_computed) > int(q8.n_fresh)
