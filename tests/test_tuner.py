"""Tuner stack: GP regression, hypervolume, EHVI/mEHVI, tuning loop modes."""
import jax
import numpy as np
import pytest

from repro.core.tuner import ehvi, estimator, fastpgt, gp, pareto
from repro.core.tuner import params as pspace


def test_undersized_ef_grid_rejected_before_any_build():
    """k > min(ef_grid) must fail up front with a clear ValueError, not as
    a knn_search error mid-estimation with builds already paid for."""
    r = np.random.default_rng(0)
    data = r.normal(size=(64, 8)).astype(np.float32)
    with pytest.raises(ValueError, match=r"min\(ef_grid\)"):
        estimator.estimate("vamana", data, data[:4], None,
                           [dict(L=16, M=8, alpha=1.2)], k=10,
                           ef_grid=[4, 20, 40])
    with pytest.raises(ValueError, match=r"min\(ef_grid\)"):
        fastpgt.tune("vamana", data, data[:4], budget=2, batch=1, k=10,
                     ef_grid=[8])
    # the defaulted grid always satisfies the bound
    assert min(estimator.resolve_ef_grid(10, None)) >= 10
    assert estimator.resolve_ef_grid(3, [16, 32]) == [16, 32]


def test_gp_interpolates():
    r = np.random.default_rng(0)
    x = r.random((30, 2))
    y = np.sin(3 * x[:, 0]) + x[:, 1] ** 2
    g = gp.fit(x, y)
    mean, var = gp.predict(g, x)
    np.testing.assert_allclose(np.asarray(mean), y, atol=0.1)
    xq = r.random((10, 2))
    mean_q, var_q = gp.predict(g, xq)
    yq = np.sin(3 * xq[:, 0]) + xq[:, 1] ** 2
    assert float(np.mean(np.abs(np.asarray(mean_q) - yq))) < 0.25
    assert bool(np.all(np.asarray(var_q) >= 0))


def test_gp_posterior_sampling_moments():
    r = np.random.default_rng(1)
    x = r.random((20, 2))
    y = x[:, 0] * 2
    g = gp.fit(x, y)
    xq = r.random((5, 2))
    s = np.asarray(gp.sample(g, xq, jax.random.PRNGKey(0), 2000))
    mean, var = gp.predict(g, xq)
    np.testing.assert_allclose(s.mean(0), np.asarray(mean), atol=0.1)


def test_hypervolume_known_values():
    ref = np.array([0.0, 0.0])
    pts = np.array([[1.0, 1.0]])
    assert pareto.hypervolume_2d(pts, ref) == pytest.approx(1.0)
    pts = np.array([[2.0, 1.0], [1.0, 2.0]])
    assert pareto.hypervolume_2d(pts, ref) == pytest.approx(3.0)
    # dominated point adds nothing
    pts = np.array([[2.0, 1.0], [1.0, 2.0], [0.5, 0.5]])
    assert pareto.hypervolume_2d(pts, ref) == pytest.approx(3.0)


def test_non_dominated_mask():
    pts = np.array([[1, 5], [2, 4], [3, 3], [2, 2], [0, 6]])
    mask = pareto.non_dominated_mask(pts)
    np.testing.assert_array_equal(mask, [True, True, True, False, True])


def test_balanced_point():
    pts = np.array([[10.0, 0.1], [5.0, 0.5], [1.0, 1.0]])
    bal = pareto.balanced_point(pts)
    np.testing.assert_array_equal(bal, [5.0, 0.5])


def test_ehvi_prefers_improving_candidates():
    r = np.random.default_rng(2)
    x = r.random((12, 2))
    y1 = x[:, 0]
    y2 = 1 - x[:, 0]
    g1 = gp.fit(x, y1)
    g2 = gp.fit(x, y2)
    front = pareto.pareto_front(np.stack([y1, y2], 1))
    ref = np.array([-0.2, -0.2])
    cands = np.array([[0.95, 0.5], [0.05, 0.5]])
    scores = ehvi.ehvi_scores(g1, g2, cands, front, ref,
                              jax.random.PRNGKey(0), n_samples=64)
    assert scores.shape == (2,)
    assert np.all(scores >= -1e-9)


def test_mehvi_batch_distinct():
    r = np.random.default_rng(3)
    x = r.random((10, 2))
    y = np.stack([x[:, 0], 1 - x[:, 0]], 1)
    g1 = gp.fit(x, y[:, 0])
    g2 = gp.fit(x, y[:, 1])
    front = pareto.pareto_front(y)
    ref = pareto.default_reference(y)
    cands = r.random((12, 2))
    idx = ehvi.select_batch_mehvi(g1, g2, cands, front, ref, 4,
                                  jax.random.PRNGKey(1), n_samples=16)
    assert len(idx) == 4 and len(set(idx)) == 4


def test_param_space_roundtrip():
    for pg in ("hnsw", "vamana", "nsg"):
        sp = pspace.space(pg, scale=0.2)
        r = np.random.default_rng(0)
        xs = sp.sample(r, 16)
        for x in xs:
            cfg = sp.decode(x)
            bp = pspace.to_build_params(pg, cfg)
            assert bp is not None
        g = sp.grid(3)
        assert g.shape[1] == sp.d


def test_theorem1_r_removed_from_spaces():
    """Per Theorem 1, R is not a tunable dimension in any space."""
    for pg in ("hnsw", "vamana", "nsg"):
        names = [d.name for d in pspace.space(pg).dims]
        assert "R" not in names


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["random", "random_plus", "grid"])
def test_tune_modes_smoke(mode):
    data, queries = estimator.make_dataset(400, 8, 20, seed=1)
    res = fastpgt.tune("vamana", data, queries, mode=mode, budget=4,
                       batch=2, seed=0, scale=0.1, build_batch_size=256,
                       ef_grid=[10, 20])
    assert len(res.objectives) == 4
    assert res.t_estimate > 0
    assert res.counters.total > 0
    if mode == "random_plus":
        assert res.counters.total < res.counters.total_base


@pytest.mark.slow
def test_tune_fastpgt_vs_vdtuner_dist_savings():
    data, queries = estimator.make_dataset(500, 8, 20, seed=2)
    kw = dict(budget=6, batch=3, seed=3, scale=0.1, build_batch_size=256,
              ef_grid=[10, 20], mc_samples=16)
    fast = fastpgt.tune("vamana", data, queries, mode="fastpgt", **kw)
    slow = fastpgt.tune("vamana", data, queries, mode="vdtuner", **kw)
    assert fast.counters.total < slow.counters.total
    assert fast.best_qps_at(0.0) > 0


@pytest.mark.slow
def test_tuned_hnsw_cosine_hits_recall_target():
    """Acceptance: a tuned HNSW on a cosine-metric synthetic dataset reaches
    recall@10 >= 0.9 at some ef in the default grid."""
    data, queries = estimator.make_dataset(500, 12, 25, seed=9)
    from repro.core import eval as evallib
    gt = evallib.ground_truth(data, queries, 10, metric="cosine")
    cfgs = [{"efc": 32, "M": 12}, {"efc": 48, "M": 16}]
    rec = estimator.estimate("hnsw", data, queries, gt, cfgs, group_size=2,
                             metric="cosine")
    best = max(p.recall for e in rec.estimates for p in e.points)
    assert best >= 0.9, f"best cosine recall {best}"


def test_tune_metric_threads_to_result():
    data, queries = estimator.make_dataset(300, 8, 15, seed=4)
    res = fastpgt.tune("vamana", data, queries, mode="random", budget=2,
                       batch=2, seed=0, scale=0.1, build_batch_size=256,
                       ef_grid=[10], metric="cosine")
    assert res.metric == "cosine"
    assert res.summary()["metric"] == "cosine"
    assert all(r >= 0 for _, r in res.objectives)


@pytest.mark.slow
def test_estimator_groups_match_singles():
    """Grouped estimation returns the same (recall) objectives as
    independent estimation — sharing never changes measured quality."""
    data, queries = estimator.make_dataset(400, 8, 30, seed=5)
    from repro.core import eval as evallib
    gt = evallib.ground_truth(data, queries, 10)
    cfgs = [{"L": 24, "M": 8, "alpha": 1.1}, {"L": 32, "M": 12, "alpha": 1.3}]
    grouped = estimator.estimate("vamana", data, queries, gt, cfgs,
                                 group_size=2, ef_grid=[20])
    single = estimator.estimate("vamana", data, queries, gt, cfgs,
                                group_size=1, ef_grid=[20])
    for a, b in zip(grouped.estimates, single.estimates):
        assert a.recall == pytest.approx(b.recall, abs=1e-6)
