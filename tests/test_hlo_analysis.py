"""HLO analyzer: trip-count correction, dot FLOPs, collective byte parse."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


def test_scan_trip_count_correction():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    mc = H.analyze(txt)
    analytic = 10 * 2 * 128 * 256 * 256
    assert abs(mc.flops - analytic) / analytic < 0.01
    assert 10 in mc.trip_counts.values()


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    mc = H.analyze(txt)
    assert mc.flops == 2 * 64 * 128 * 32


def test_collective_byte_parsing_synthetic():
    txt = """
HloModule m

%region_0.1 (a: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %r = f32[4]{0} add(%x, %x)
}

ENTRY %main (p: f32[1024,512]) -> f32[1024,512] {
  %p0 = f32[1024,512]{1,0} parameter(0)
  %ag = f32[2048,512]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = bf16[1024,512]{1,0} all-reduce(%p0), to_apply=%region_0.1
  %cp = f32[1024,512]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %out = f32[1024,512]{1,0} add(%p0, %p0)
}
"""
    mc = H.analyze(txt)
    assert mc.coll_bytes["all-gather"] == 2048 * 512 * 4
    assert mc.coll_bytes["all-reduce"] == 1024 * 512 * 2
    assert mc.coll_bytes["collective-permute"] == 1024 * 512 * 4
    eff = H.effective_collective_bytes(mc.coll_bytes)
    assert eff == (2048 * 512 * 4 + 2 * 1024 * 512 * 2 + 1024 * 512 * 4)


def test_nested_while_multiplies():
    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    mc = H.analyze(txt)
    analytic = 3 * 5 * 2 * 32 * 64 * 64
    assert abs(mc.flops - analytic) / analytic < 0.05
