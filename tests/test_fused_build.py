"""Fused build (DESIGN.md §12): at test scale build_impl="fused" must be
bit-identical to "per_batch" — graphs AND counters — because the fused
step traces the very functions the per_batch loop dispatches.  (At large
n the staged path's eager prune-stage reduction admits a ppm-bounded FP
tie deviation; benchmarks/build_bench.py asserts that bound.)  Also pins
the dispatch contract: one compiled dispatch per fused batch step
(Vamana: per pass), versus 1 + 2m jitted dispatches per batch on the
host loop."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build as build_lib
from repro.core import commit, hnsw, nsg, prune, search, vamana

METRICS = ("l2", "ip", "cosine")


def _data(n=180, d=10, seed=3):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(size=(n, d)), jnp.float32)


VAMANA_PS = [vamana.VamanaParams(L=16, M=8, alpha=1.1),
             vamana.VamanaParams(L=20, M=8, alpha=1.3)]
HNSW_PS = [hnsw.HNSWParams(efc=16, M=8), hnsw.HNSWParams(efc=20, M=8)]
NSG_PS = [nsg.NSGParams(K=8, L=16, M=8), nsg.NSGParams(K=10, L=20, M=8)]


def _assert_graphs_equal(ids_a, dist_a, ids_b, dist_b):
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    da, db = np.asarray(dist_a), np.asarray(dist_b)
    np.testing.assert_array_equal(np.isinf(da), np.isinf(db))
    np.testing.assert_array_equal(da[~np.isinf(da)], db[~np.isinf(db)])


def _pair(builder, ps, **kw):
    a = builder(_data(), ps, batch_size=64, build_impl="per_batch", **kw)
    b = builder(_data(), ps, batch_size=64, build_impl="fused", **kw)
    assert a.counters.as_dict() == b.counters.as_dict()
    return a, b


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("visited_impl", ("dense", "hash"))
@pytest.mark.parametrize("sharing", (True, False))
def test_vamana_fused_identity(metric, visited_impl, sharing):
    a, b = _pair(vamana.build_multi_vamana, VAMANA_PS, metric=metric,
                 visited_impl=visited_impl, use_eso=sharing, use_epo=sharing)
    _assert_graphs_equal(a.g.ids, a.g.dist, b.g.ids, b.g.dist)


@pytest.mark.parametrize("metric", METRICS)
def test_hnsw_fused_identity(metric):
    a, b = _pair(hnsw.build_multi_hnsw, HNSW_PS, metric=metric)
    _assert_graphs_equal(a.g.layer_ids, a.g.layer_dist,
                         b.g.layer_ids, b.g.layer_dist)
    assert a.g.entry == b.g.entry and a.g.top == b.g.top


@pytest.mark.parametrize("metric", METRICS)
def test_nsg_fused_identity(metric):
    a, b = _pair(nsg.build_multi_nsg, NSG_PS, metric=metric)
    _assert_graphs_equal(a.g.ids, a.g.dist, b.g.ids, b.g.dist)
    assert a.entry == b.entry


@pytest.mark.slow
@pytest.mark.parametrize("visited_impl", ("dense", "hash"))
@pytest.mark.parametrize("sharing", (True, False))
def test_hnsw_nsg_fused_identity_thorough(visited_impl, sharing):
    a, b = _pair(hnsw.build_multi_hnsw, HNSW_PS, visited_impl=visited_impl,
                 use_eso=sharing, use_epo=sharing)
    _assert_graphs_equal(a.g.layer_ids, a.g.layer_dist,
                         b.g.layer_ids, b.g.layer_dist)
    a, b = _pair(nsg.build_multi_nsg, NSG_PS, visited_impl=visited_impl,
                 use_eso=sharing, use_epo=sharing)
    _assert_graphs_equal(a.g.ids, a.g.dist, b.g.ids, b.g.dist)


def test_resolve_build_impl_rejects_unknown():
    with pytest.raises(ValueError, match="build_impl"):
        build_lib.resolve_build_impl("bogus")
    with pytest.raises(ValueError, match="build_impl"):
        vamana.build_multi_vamana(_data(64), VAMANA_PS, build_impl="eager")


# ---- dispatch-count contract (DESIGN.md §12) -------------------------------

# Every module-level jitted callable a build can invoke from Python.
_TARGETS = ((search, "beam_search"), (prune, "rng_prune"),
            (commit, "add_reverse_edges"), (build_lib, "insert_batch"),
            (build_lib, "nsg_insert_batch"),
            (build_lib, "fused_vamana_pass"))


class _Counting:
    def __init__(self, fn):
        self.fn, self.calls = fn, 0

    def __call__(self, *a, **kw):
        self.calls += 1
        return self.fn(*a, **kw)


def _count_dispatches(fn):
    """Python-level jitted-callable invocations during fn() (call only
    after a warmup run: tracing itself invokes wrapped inner names)."""
    shims = [(mod, name, _Counting(getattr(mod, name)))
             for mod, name in _TARGETS]
    for mod, name, shim in shims:
        setattr(mod, name, shim)
    try:
        fn()
    finally:
        for mod, name, shim in shims:
            setattr(mod, name, shim.fn)
    return {name: shim.calls for _, name, shim in shims}


def test_fused_vamana_dispatch_pin():
    """A warmed fused Vamana build is ONE jitted dispatch for the whole
    insertion pass — and zero Python-level calls to the stage functions
    (they are traced into the fused program, not dispatched)."""
    data = _data()

    def build(impl):
        return vamana.build_multi_vamana(data, VAMANA_PS, batch_size=64,
                                         build_impl=impl)

    build("fused")                                   # warmup/compile
    cache_size = getattr(build_lib.fused_vamana_pass, "_cache_size",
                         lambda: None)()
    counts = _count_dispatches(lambda: build("fused"))
    assert counts == {"beam_search": 0, "rng_prune": 0,
                      "add_reverse_edges": 0, "insert_batch": 0,
                      "nsg_insert_batch": 0, "fused_vamana_pass": 1}
    if cache_size is not None:                       # compile-count audit
        assert build_lib.fused_vamana_pass._cache_size() == cache_size


def test_per_batch_dispatch_structure():
    """The host loop's measured structure: 1 search + m prunes + m reverse
    commits per batch — the 1 + 2m dispatches the fused path collapses."""
    data = _data()        # n=180, batch 64 -> 3 batches, m=2
    n_batches, m = 3, len(VAMANA_PS)

    def build():
        return vamana.build_multi_vamana(data, VAMANA_PS, batch_size=64,
                                         build_impl="per_batch")

    build()                                          # warmup/compile
    counts = _count_dispatches(build)
    assert counts == {"beam_search": n_batches, "rng_prune": n_batches * m,
                      "add_reverse_edges": n_batches * m, "insert_batch": 0,
                      "nsg_insert_batch": 0, "fused_vamana_pass": 0}


def test_insert_batch_single_dispatch():
    """One fused batch step (the HNSW-shaped entry point) invokes no other
    jitted callable after warmup, and reuses its compiled program."""
    m, n, d, b, m_max = 2, 96, 8, 16, 8
    r = np.random.default_rng(5)
    data = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    gids = jnp.full((m, n, m_max), -1, jnp.int32)
    gdist = jnp.full((m, n, m_max), jnp.inf, jnp.float32)
    u = jnp.arange(b, dtype=jnp.int32)
    row_mask = jnp.ones((b,), bool)
    kw = dict(ef_max=16, max_hops=8, share_cache=True, use_epo=True,
              metric="l2", visited_impl="dense", expand_width=1, k_in=8,
              m_max=m_max)

    def step():
        return build_lib.insert_batch(
            gids, gdist, data, u, row_mask, data[u],
            jnp.array([8, 8], jnp.int32), jnp.array([4, 4], jnp.int32),
            jnp.array([1.0, 1.1], jnp.float32),
            jnp.zeros((b, m), jnp.int32), None, None, **kw)

    jnp.asarray(step()[0]).block_until_ready()       # warmup/compile
    cache_size = getattr(build_lib.insert_batch, "_cache_size",
                         lambda: None)()
    counts = _count_dispatches(step)
    assert counts["beam_search"] == 0
    assert counts["rng_prune"] == 0
    assert counts["add_reverse_edges"] == 0
    if cache_size is not None:
        assert build_lib.insert_batch._cache_size() == cache_size
