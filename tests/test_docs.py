"""Docs consistency: every DESIGN.md § reference in src/ and tests/ must
resolve (mirrors the CI step running tools/check_docs.py)."""
import importlib.util
import pathlib

_PATH = (pathlib.Path(__file__).resolve().parent.parent
         / "tools" / "check_docs.py")
_spec = importlib.util.spec_from_file_location("check_docs", _PATH)
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_no_broken_design_references():
    assert check_docs.broken_references() == []


def test_resolver_sections_and_items():
    secs = check_docs.design_sections(
        "## §1 Scope\nintro\n## §2 Invariants\n1. **one.**\n2. **two.**\n"
        "## §Perf — notes\nbody\n")
    assert check_docs.resolves("1", secs)
    assert check_docs.resolves("2.2", secs)
    assert check_docs.resolves("Perf", secs)
    assert not check_docs.resolves("9", secs)
    assert not check_docs.resolves("2.7", secs)


def test_reference_extraction_handles_wrapping_and_chains():
    toks = check_docs.file_references(
        "# counters per the paper's model (DESIGN.md\n"
        "# §3): base...\n"
        "# lockstep hardware (DESIGN.md §3, §Perf iteration 5)\n"
        "# unrelated: EXPERIMENTS.md §Dry-run is not checked\n")
    assert toks == ["3", "3", "Perf"]
