"""Metric abstraction: registry, similarity->distance convention, monotone
equivalences, and end-to-end metric threading through builders + serving."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as evallib
from repro.core import graph, knng, metric, prune, vamana
from repro.serve import retrieval


def test_registry_and_resolve():
    assert metric.resolve("l2") is metric.L2
    assert metric.resolve("ip") is metric.IP
    assert metric.resolve("cosine") is metric.COSINE
    assert metric.resolve(metric.COSINE) is metric.COSINE
    assert set(metric.names()) >= {"l2", "ip", "cosine"}
    with pytest.raises(ValueError, match="unknown metric"):
        metric.resolve("manhattan")
    with pytest.raises(ValueError, match="kernel form"):
        metric.Metric("bad", "hamming")


def test_register_custom_metric():
    m = metric.register(metric.Metric("unit-ip", "ip", normalize=True))
    try:
        assert metric.resolve("unit-ip") is m
    finally:
        metric._REGISTRY.pop("unit-ip")


def test_prepare_is_idempotent():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(40, 8)), jnp.float32)
    once = metric.COSINE.prepare(x)
    twice = metric.COSINE.prepare(once)
    np.testing.assert_allclose(once, twice, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(metric.L2.prepare(x)),
                                  np.asarray(x))


def test_cosine_equals_l2_ordering_on_unit_sphere():
    """On unit vectors, 1 - cos and squared L2 are monotone transforms of
    each other (||a-b||^2 = 2(1 - <a,b>)) — top-k sets must coincide."""
    r = np.random.default_rng(1)
    data = metric.normalize(jnp.asarray(r.normal(size=(300, 10)), jnp.float32))
    q = metric.normalize(jnp.asarray(r.normal(size=(20, 10)), jnp.float32))
    ids_cos, d_cos = knng.exact_knn(data, q, 5, metric="cosine")
    ids_l2, d_l2 = knng.exact_knn(data, q, 5, metric="l2")
    np.testing.assert_array_equal(np.asarray(ids_cos), np.asarray(ids_l2))
    np.testing.assert_allclose(2.0 * np.asarray(d_cos), np.asarray(d_l2),
                               rtol=1e-4, atol=1e-4)


def test_ip_ground_truth_is_argmax_dot():
    r = np.random.default_rng(2)
    data = jnp.asarray(r.normal(size=(150, 6)), jnp.float32)
    q = jnp.asarray(r.normal(size=(9, 6)), jnp.float32)
    gt = evallib.ground_truth(data, q, 4, metric="ip")
    exp = np.argsort(-np.asarray(q) @ np.asarray(data).T, axis=1)[:, :4]
    np.testing.assert_array_equal(np.asarray(gt), exp)


def test_medoid_per_metric():
    r = np.random.default_rng(3)
    data = jnp.asarray(r.normal(size=(80, 5)) + 2.0, jnp.float32)
    c = np.mean(np.asarray(data), axis=0)
    m_l2 = int(graph.medoid(data, "l2"))
    assert m_l2 == int(np.argmin(np.sum((np.asarray(data) - c) ** 2, -1)))
    m_ip = int(graph.medoid(data, "ip"))
    assert m_ip == int(np.argmax(np.asarray(data) @ c))


def test_with_distances_metric():
    r = np.random.default_rng(4)
    data = jnp.asarray(r.normal(size=(20, 4)), jnp.float32)
    ids = jnp.asarray([[1, 2, graph.INVALID], [0, 3, 4]], jnp.int32)
    d_ip = graph.with_distances(data, ids, "ip")
    x = np.asarray(data)
    assert d_ip[0, 0] == pytest.approx(1.0 - x[0] @ x[1], abs=1e-5)
    assert np.isinf(np.asarray(d_ip)[0, 2])


def test_pairwise_candidate_dist_uses_metric():
    """The alpha-rule's occlusion distances must be in metric units, clamped
    at 0 for raw ip: negative pair distances would invert the alpha rule
    (larger alpha dominating more) and void EPO's pair-skip soundness."""
    r = np.random.default_rng(5)
    data = jnp.asarray(r.normal(size=(30, 7)), jnp.float32)
    cand = jnp.asarray([[0, 3, 9, 12]], jnp.int32)
    pd_ip = prune.pairwise_candidate_dist(data, cand, "ip")
    x = np.asarray(data)[np.asarray(cand)[0]]
    np.testing.assert_allclose(np.asarray(pd_ip)[0],
                               np.maximum(1.0 - x @ x.T, 0.0),
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.all(pd_ip >= 0.0))


def test_build_results_record_metric(small_dataset):
    data, _ = small_dataset
    res = vamana.build_vamana(data[:300], vamana.VamanaParams(16, 8, 1.1),
                              batch_size=128, metric="cosine")
    assert res.metric == "cosine"


def test_retrieval_index_no_per_query_renormalization():
    """Cosine index: search_keys are normalized ONCE at build; the stored
    matrix is reused verbatim by retrieval_attention (the old per-query
    full-matrix renormalization hack is gone)."""
    r = np.random.default_rng(6)
    keys = jnp.asarray(r.normal(size=(200, 8)), jnp.float32)
    vals = jnp.asarray(r.normal(size=(200, 8)), jnp.float32)
    idx = retrieval.build_index(keys, vals,
                                vamana.VamanaParams(L=16, M=8, alpha=1.1),
                                metric="cosine")
    norms = jnp.linalg.norm(idx.search_keys, axis=-1)
    np.testing.assert_allclose(np.asarray(norms), 1.0, atol=1e-5)
    assert idx.metric == "cosine" and idx.kernel == "ip"
    out, res = retrieval.retrieval_attention(idx, keys[:4] * 2, top_k=8,
                                             ef=16)
    assert out.shape == (4, 8)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_retrieval_native_ip_ranking_is_exact_mips():
    """Native-ip index ranks by raw inner product — pool order must equal
    exhaustive MIPS order for the retrieved prefix."""
    r = np.random.default_rng(7)
    keys = jnp.asarray(r.normal(size=(300, 8)), jnp.float32)
    vals = jnp.asarray(r.normal(size=(300, 8)), jnp.float32)
    idx = retrieval.build_index(keys, vals,
                                vamana.VamanaParams(L=32, M=12, alpha=1.2),
                                metric="ip")
    q = keys[r.integers(0, 300, 6)] * 3.0
    _, res = retrieval.retrieval_attention(idx, q, top_k=5, ef=64)
    sims = np.asarray(q) @ np.asarray(keys).T
    got = np.asarray(res.pool_ids)
    for b in range(6):
        ids = got[b][got[b] >= 0]
        order = np.argsort(-sims[b][ids], kind="stable")
        assert list(ids) == list(ids[order])     # retrieved in MIPS order
