"""Mesh-partitioned scatter-gather search (DESIGN.md §11): differential
parity against the serial decomposition, num_shards=1 bit-identity against
``knn_search``, counter accounting under psum, uneven remainder shards,
partitioner invariants, and the n=10k recall bars (slow lane).

CI runs this under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(tests/conftest.py forces it for local runs too) so the shard_map path
actually crosses device boundaries; the same tests pass on one device
(1-way mesh).  Fast-lane batches stay <= 32 queries so interpret-mode
kernel dispatch stays cheap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as evallib
from repro.core import graph, knng, search
from repro.core.graph import INVALID, random_knng_ids
from repro.distributed import sharding as sharding_lib

METRICS = ["l2", "ip", "cosine"]


def serial_scatter_gather(sg: graph.ShardedGraph, queries, k, ef, *,
                          metric="l2", visited_impl="dense",
                          expand_width=1):
    """The decomposition ``sharded_knn_search`` distributes, executed as a
    host-side fold: per-shard ``knn_search`` (full-``ef`` pools), global-id
    restore, left-to-right ``_merge_topk`` fold in shard order, counters
    summed / hops maxed.  The mesh path must match this bit-for-bit — that
    is the differential contract separating "distributed execution" bugs
    (specs, psum, id restoration, padding) from search-semantics bugs
    (covered by tests/test_oracle.py)."""
    pool_i = pool_d = None
    n_fresh = n_comp = hops = 0
    for s in range(sg.num_shards):
        res = search.knn_search(
            sg.ids[s], sg.data[s], queries, ef, ef, int(sg.entries[s]),
            metric=metric, visited_impl=visited_impl,
            expand_width=expand_width)
        gids = jnp.where(res.pool_ids == INVALID, INVALID,
                         sg.global_ids[s][jnp.maximum(res.pool_ids, 0)])
        if pool_i is None:
            pool_i, pool_d = gids, res.pool_dist
        else:
            pool_i, pool_d, _ = search._merge_topk(
                pool_i, pool_d, jnp.zeros_like(pool_i, bool), gids,
                res.pool_dist)
        n_fresh += int(res.n_fresh)
        n_comp += int(res.n_computed)
        hops = max(hops, int(res.hops))
    return pool_i[:, :k], pool_d[:, :k], n_fresh, n_comp, hops


def _dataset(n, d=16, b=16, seed=0):
    r = np.random.default_rng(seed)
    data = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    queries = data[r.integers(0, n, b)] + 0.1 * jnp.asarray(
        r.normal(size=(b, d)), jnp.float32)
    return data, queries


def _assert_mesh_matches_serial(sg, queries, k, ef, **kw):
    res = search.sharded_knn_search(sg, queries, k, ef, **kw)
    ri, rd, nf, nc, hp = serial_scatter_gather(sg, queries, k, ef, **kw)
    np.testing.assert_array_equal(np.asarray(res.pool_ids), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(res.pool_dist), np.asarray(rd))
    assert int(res.n_fresh) == nf, "psum'd n_fresh != sum of shard counts"
    assert int(res.n_computed) == nc
    assert int(res.hops) == hp
    return res


def test_num_shards_1_bit_identical_to_knn_search(small_dataset):
    """The acceptance pin: a 1-shard container searched through the mesh
    path returns byte-identical pools AND counters to the current
    ``knn_search`` from the same entry point."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    sg = graph.partition(data, 1, graph_ids=adj)
    ref = search.knn_search(adj, data, queries, 10, 30,
                            int(sg.entries[0]))
    res = search.sharded_knn_search(sg, queries, 10, 30)
    np.testing.assert_array_equal(np.asarray(res.pool_ids),
                                  np.asarray(ref.pool_ids))
    np.testing.assert_array_equal(np.asarray(res.pool_dist),
                                  np.asarray(ref.pool_dist))
    assert int(res.n_fresh) == int(ref.n_fresh)
    assert int(res.n_computed) == int(ref.n_computed)
    assert int(res.hops) == int(ref.hops)


@pytest.mark.parametrize("impl", ["dense", "hash"])
@pytest.mark.parametrize("num_shards", [2, 4])
@pytest.mark.parametrize("assignment", ["chunked", "random"])
def test_mesh_matches_serial_scatter_gather(num_shards, assignment, impl):
    data, queries = _dataset(600, b=16, seed=num_shards)
    sg = graph.partition(data, num_shards, assignment=assignment,
                         degree=10)
    _assert_mesh_matches_serial(sg, queries, 8, 24, visited_impl=impl)


@pytest.mark.parametrize("width", [1, 4])
def test_mesh_parity_multi_expansion(width):
    """expand_width semantics survive sharding unchanged."""
    data, queries = _dataset(600, b=16, seed=3)
    sg = graph.partition(data, 4, degree=10)
    _assert_mesh_matches_serial(sg, queries, 8, 24, expand_width=width)


@pytest.mark.parametrize("metric", METRICS)
def test_mesh_parity_metrics(metric):
    data, queries = _dataset(500, b=16, seed=5)
    sg = graph.partition(data, 2, degree=10, metric=metric)
    _assert_mesh_matches_serial(sg, queries, 8, 24, metric=metric)


def test_uneven_remainder_shards():
    """n % num_shards != 0: remainder shards pad; padding rows must never
    surface (every returned id is a real global id or INVALID tail)."""
    n = 603
    data, queries = _dataset(n, b=16, seed=9)
    sg = graph.partition(data, 4, degree=10)
    assert sg.shard_rows == 151                      # ceil(603 / 4)
    assert [int(c) for c in sg.counts] == [151, 151, 151, 150]
    res = _assert_mesh_matches_serial(sg, queries, 8, 24)
    ids = np.asarray(res.pool_ids)
    assert ids.max() < n
    real = ids[ids != INVALID]
    assert real.size                                 # found something
    assert np.all(real >= 0)
    # k slots fill: with ef=24 per shard there are plenty of candidates
    assert np.all(ids != INVALID)


def test_row_mask_padding_rows_do_no_work():
    data, queries = _dataset(400, b=8, seed=1)
    sg = graph.partition(data, 2, degree=10)
    mask = jnp.zeros(8, bool).at[:3].set(True)
    res = search.sharded_knn_search(sg, queries, 8, 16, row_mask=mask)
    assert bool(jnp.all(res.pool_ids[3:] == INVALID))
    assert bool(jnp.all(res.pool_ids[:3] != INVALID))


def test_partition_covers_corpus_exactly_once():
    data, _ = _dataset(103, b=1, seed=2)
    for assignment in graph.ASSIGNMENTS:
        sg = graph.partition(data, 4, assignment=assignment, degree=8)
        gids = np.asarray(sg.global_ids)
        real = gids[gids != INVALID]
        assert sorted(real.tolist()) == list(range(103))
        # deterministic under seed
        sg2 = graph.partition(data, 4, assignment=assignment, degree=8)
        np.testing.assert_array_equal(gids, np.asarray(sg2.global_ids))
    chunked = graph.shard_assignment(103, 4)
    assert [p[0] for p in chunked] == [0, 26, 52, 78]   # contiguous runs


def test_partition_validates():
    data, _ = _dataset(10, b=1)
    with pytest.raises(ValueError, match="num_shards"):
        graph.partition(data, 0)
    with pytest.raises(ValueError, match="num_shards"):
        graph.partition(data, 11)
    with pytest.raises(ValueError, match="assignment"):
        graph.partition(data, 2, assignment="hashed")
    with pytest.raises(ValueError, match="k="):
        sg = graph.partition(data, 2, degree=4)
        search.sharded_knn_search(sg, data[:2], 8, 4)


def test_induced_partition_drops_only_cross_shard_edges():
    data, _ = _dataset(80, b=1, seed=4)
    adj, _ = knng.build_knng(data, 8)
    sg = graph.partition(data, 2, graph_ids=adj)
    adj_np = np.asarray(adj)
    for s in range(2):
        part = np.asarray(sg.global_ids[s][:int(sg.counts[s])])
        in_shard = np.isin(adj_np[part], part)
        local = np.asarray(sg.ids[s][:int(sg.counts[s])])
        # kept edge count matches the in-shard edge count, and every kept
        # edge maps back to the original global neighbor
        assert (local != INVALID).sum() == in_shard.sum()
        restored = np.where(local == INVALID, INVALID,
                            part[np.maximum(local, 0)])
        np.testing.assert_array_equal(
            restored[in_shard], adj_np[part][in_shard])


def test_mesh_with_multiple_shards_per_device_matches_serial():
    """4 shards on a 2-device mesh: each mesh slot folds its 2 local
    shards, then slots merge — the tree fold must still equal the serial
    shard-order fold (pool-wins ties make the reduction order-stable)."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (conftest forces 4)")
    data, queries = _dataset(600, b=16, seed=8)
    mesh = sharding_lib.search_mesh(4, devices=jax.devices()[:2])
    assert mesh.shape["shard"] == 2
    sg = graph.partition(data, 4, degree=10, mesh=mesh)
    # search defaults to the placement mesh — no mesh= needed
    res = search.sharded_knn_search(sg, queries, 8, 24)
    ri, rd, nf, nc, hp = serial_scatter_gather(sg, queries, 8, 24)
    np.testing.assert_array_equal(np.asarray(res.pool_ids), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(res.pool_dist), np.asarray(rd))
    assert (int(res.n_fresh), int(res.n_computed), int(res.hops)) == \
        (nf, nc, hp)


def test_search_mesh_adapts_to_device_count():
    for s in (1, 2, 4):
        mesh = sharding_lib.search_mesh(s)
        assert s % mesh.shape["shard"] == 0
        assert mesh.shape["shard"] <= len(jax.devices())
    mesh3 = sharding_lib.search_mesh(3, devices=jax.devices()[:2])
    assert mesh3.shape["shard"] == 1                 # 3 shards, 2 devices
    with pytest.raises(ValueError, match="num_shards"):
        sharding_lib.search_mesh(0)


# ---------------------------------------------------------------------------
# n=10k acceptance bars (nightly lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("metric", METRICS)
def test_chunked_exact_parity_10k(metric):
    """Acceptance: shards in {2, 4}, chunked disjoint partition, n=10k —
    the mesh path's merged pools match the serial decomposition exactly
    (ids byte-equal, dists byte-equal, counters psum-exact) under forced
    multi-device CPU, for all three metrics."""
    n, b, k, ef = 10_000, 32, 10, 32
    r = np.random.default_rng(17)
    data = jnp.asarray(r.normal(size=(n, 16)), jnp.float32)
    queries = data[r.integers(0, n, b)] + 0.1 * jnp.asarray(
        r.normal(size=(b, 16)), jnp.float32)
    adj = random_knng_ids(1, n, 16)
    for num_shards in (2, 4):
        sg = graph.partition(data, num_shards, assignment="chunked",
                             graph_ids=adj, metric=metric)
        _assert_mesh_matches_serial(sg, queries, k, ef, metric=metric)


@pytest.mark.slow
@pytest.mark.parametrize("metric", METRICS)
def test_random_partition_recall_10k(metric):
    """Acceptance: random partition at n=10k keeps recall@10 within 0.005
    of the unsharded search (per-shard exact-KNNG subindexes searched with
    the full ef each: scatter-gather explores more total candidates)."""
    n, b, k, ef, deg = 10_000, 32, 10, 64, 16
    r = np.random.default_rng(23)
    data = jnp.asarray(r.normal(size=(n, 16)), jnp.float32)
    queries = data[r.integers(0, n, b)] + 0.1 * jnp.asarray(
        r.normal(size=(b, 16)), jnp.float32)
    gt = evallib.ground_truth(data, queries, k, metric=metric)
    adj, _ = knng.build_knng(data, deg, metric=metric)
    base = search.knn_search(adj, data, queries, k, ef, 0, metric=metric)
    rec_base = evallib.recall_at_k(base.pool_ids, gt)
    sg = graph.partition(data, 4, assignment="random", degree=deg,
                         metric=metric)
    res = search.sharded_knn_search(sg, queries, k, ef, metric=metric)
    rec = evallib.recall_at_k(res.pool_ids, gt)
    assert rec >= rec_base - 0.005, (rec, rec_base)
