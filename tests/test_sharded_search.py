"""Mesh-partitioned scatter-gather search (DESIGN.md §11): differential
parity against the serial decomposition, num_shards=1 bit-identity against
``knn_search``, counter accounting under psum, uneven remainder shards,
partitioner invariants — including the kmeans partitioner's coverage /
balance / determinism / no-empty-shard properties and the routed-search
degeneracy pins (DESIGN.md §13: routed_shards=S bit-identical to
scatter-gather, validation, routed row masking) — and the n=10k recall
bars (slow lane).

CI runs this under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(tests/conftest.py forces it for local runs too) so the shard_map path
actually crosses device boundaries; the same tests pass on one device
(1-way mesh).  Fast-lane batches stay <= 32 queries so interpret-mode
kernel dispatch stays cheap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as evallib
from repro.core import graph, knng, search
from repro.core.graph import INVALID, random_knng_ids
from repro.distributed import sharding as sharding_lib

METRICS = ["l2", "ip", "cosine"]


def serial_scatter_gather(sg: graph.ShardedGraph, queries, k, ef, *,
                          metric="l2", visited_impl="dense",
                          expand_width=1):
    """The decomposition ``sharded_knn_search`` distributes, executed as a
    host-side fold: per-shard ``knn_search`` (full-``ef`` pools), global-id
    restore, left-to-right ``_merge_topk`` fold in shard order, counters
    summed / hops maxed.  The mesh path must match this bit-for-bit — that
    is the differential contract separating "distributed execution" bugs
    (specs, psum, id restoration, padding) from search-semantics bugs
    (covered by tests/test_oracle.py)."""
    pool_i = pool_d = None
    n_fresh = n_comp = hops = 0
    for s in range(sg.num_shards):
        res = search.knn_search(
            sg.ids[s], sg.data[s], queries, ef, ef, int(sg.entries[s]),
            metric=metric, visited_impl=visited_impl,
            expand_width=expand_width)
        gids = jnp.where(res.pool_ids == INVALID, INVALID,
                         sg.global_ids[s][jnp.maximum(res.pool_ids, 0)])
        if pool_i is None:
            pool_i, pool_d = gids, res.pool_dist
        else:
            pool_i, pool_d, _ = search._merge_topk(
                pool_i, pool_d, jnp.zeros_like(pool_i, bool), gids,
                res.pool_dist)
        n_fresh += int(res.n_fresh)
        n_comp += int(res.n_computed)
        hops = max(hops, int(res.hops))
    return pool_i[:, :k], pool_d[:, :k], n_fresh, n_comp, hops


def _dataset(n, d=16, b=16, seed=0):
    r = np.random.default_rng(seed)
    data = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    queries = data[r.integers(0, n, b)] + 0.1 * jnp.asarray(
        r.normal(size=(b, d)), jnp.float32)
    return data, queries


def _assert_mesh_matches_serial(sg, queries, k, ef, **kw):
    res = search.sharded_knn_search(sg, queries, k, ef, **kw)
    ri, rd, nf, nc, hp = serial_scatter_gather(sg, queries, k, ef, **kw)
    np.testing.assert_array_equal(np.asarray(res.pool_ids), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(res.pool_dist), np.asarray(rd))
    assert int(res.n_fresh) == nf, "psum'd n_fresh != sum of shard counts"
    assert int(res.n_computed) == nc
    assert int(res.hops) == hp
    return res


def test_num_shards_1_bit_identical_to_knn_search(small_dataset):
    """The acceptance pin: a 1-shard container searched through the mesh
    path returns byte-identical pools AND counters to the current
    ``knn_search`` from the same entry point."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    sg = graph.partition(data, 1, graph_ids=adj)
    ref = search.knn_search(adj, data, queries, 10, 30,
                            int(sg.entries[0]))
    res = search.sharded_knn_search(sg, queries, 10, 30)
    np.testing.assert_array_equal(np.asarray(res.pool_ids),
                                  np.asarray(ref.pool_ids))
    np.testing.assert_array_equal(np.asarray(res.pool_dist),
                                  np.asarray(ref.pool_dist))
    assert int(res.n_fresh) == int(ref.n_fresh)
    assert int(res.n_computed) == int(ref.n_computed)
    assert int(res.hops) == int(ref.hops)


@pytest.mark.parametrize("impl", ["dense", "hash"])
@pytest.mark.parametrize("num_shards", [2, 4])
@pytest.mark.parametrize("assignment", ["chunked", "random"])
def test_mesh_matches_serial_scatter_gather(num_shards, assignment, impl):
    data, queries = _dataset(600, b=16, seed=num_shards)
    sg = graph.partition(data, num_shards, assignment=assignment,
                         degree=10)
    _assert_mesh_matches_serial(sg, queries, 8, 24, visited_impl=impl)


@pytest.mark.parametrize("width", [1, 4])
def test_mesh_parity_multi_expansion(width):
    """expand_width semantics survive sharding unchanged."""
    data, queries = _dataset(600, b=16, seed=3)
    sg = graph.partition(data, 4, degree=10)
    _assert_mesh_matches_serial(sg, queries, 8, 24, expand_width=width)


@pytest.mark.parametrize("metric", METRICS)
def test_mesh_parity_metrics(metric):
    data, queries = _dataset(500, b=16, seed=5)
    sg = graph.partition(data, 2, degree=10, metric=metric)
    _assert_mesh_matches_serial(sg, queries, 8, 24, metric=metric)


def test_uneven_remainder_shards():
    """n % num_shards != 0: remainder shards pad; padding rows must never
    surface (every returned id is a real global id or INVALID tail)."""
    n = 603
    data, queries = _dataset(n, b=16, seed=9)
    sg = graph.partition(data, 4, degree=10)
    assert sg.shard_rows == 151                      # ceil(603 / 4)
    assert [int(c) for c in sg.counts] == [151, 151, 151, 150]
    res = _assert_mesh_matches_serial(sg, queries, 8, 24)
    ids = np.asarray(res.pool_ids)
    assert ids.max() < n
    real = ids[ids != INVALID]
    assert real.size                                 # found something
    assert np.all(real >= 0)
    # k slots fill: with ef=24 per shard there are plenty of candidates
    assert np.all(ids != INVALID)


def test_row_mask_padding_rows_do_no_work():
    data, queries = _dataset(400, b=8, seed=1)
    sg = graph.partition(data, 2, degree=10)
    mask = jnp.zeros(8, bool).at[:3].set(True)
    res = search.sharded_knn_search(sg, queries, 8, 16, row_mask=mask)
    assert bool(jnp.all(res.pool_ids[3:] == INVALID))
    assert bool(jnp.all(res.pool_ids[:3] != INVALID))


def test_partition_covers_corpus_exactly_once():
    data, _ = _dataset(103, b=1, seed=2)
    for assignment in graph.ASSIGNMENTS:
        sg = graph.partition(data, 4, assignment=assignment, degree=8)
        gids = np.asarray(sg.global_ids)
        real = gids[gids != INVALID]
        assert sorted(real.tolist()) == list(range(103))
        # deterministic under seed
        sg2 = graph.partition(data, 4, assignment=assignment, degree=8)
        np.testing.assert_array_equal(gids, np.asarray(sg2.global_ids))
    chunked = graph.shard_assignment(103, 4)
    assert [p[0] for p in chunked] == [0, 26, 52, 78]   # contiguous runs


def test_partition_validates():
    data, _ = _dataset(10, b=1)
    with pytest.raises(ValueError, match="num_shards"):
        graph.partition(data, 0)
    with pytest.raises(ValueError, match="num_shards"):
        graph.partition(data, 11)
    with pytest.raises(ValueError, match="assignment"):
        graph.partition(data, 2, assignment="hashed")
    with pytest.raises(ValueError, match="k="):
        sg = graph.partition(data, 2, degree=4)
        search.sharded_knn_search(sg, data[:2], 8, 4)


def test_row_mask_dtype_rejected():
    """Integer masks used to silently cast to 0/1 arithmetic inside the
    search; sharded_knn_search now rejects them up front (mirror of the
    k > ef guard)."""
    data, queries = _dataset(200, b=8, seed=7)
    sg = graph.partition(data, 2, degree=8)
    with pytest.raises(ValueError, match="row_mask dtype"):
        search.sharded_knn_search(sg, queries, 4, 8,
                                  row_mask=jnp.ones(8, jnp.int32))
    with pytest.raises(ValueError, match="row_mask dtype"):
        search.sharded_knn_search(sg, queries, 4, 8, routed_shards=2,
                                  row_mask=jnp.arange(8))
    # bool masks (and None) still pass
    search.sharded_knn_search(sg, queries, 4, 8,
                              row_mask=jnp.ones(8, bool))


# ---------------------------------------------------------------------------
# kmeans partitioner properties (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _clustered(n, d=16, seed=0, n_clusters=3, skew=(8, 3, 1)):
    """Gaussian clusters with skewed sizes — the balance stressor: raw
    k-means would hoard the big cluster into one shard."""
    r = np.random.default_rng(seed)
    centers = r.normal(size=(n_clusters, d)) * 6.0
    sizes = (np.array(skew) * n / sum(skew)).astype(int)
    sizes[0] += n - sizes.sum()
    rows = np.concatenate([
        centers[i] + r.normal(size=(s, d)) for i, s in enumerate(sizes)])
    return jnp.asarray(rows[r.permutation(n)], jnp.float32)


def test_kmeans_partition_covers_and_balances():
    n, S = 1200, 4
    data = _clustered(n, seed=11)
    parts = graph.shard_assignment(n, S, assignment="kmeans", data=data)
    ids = np.concatenate(parts)
    assert np.array_equal(np.sort(ids), np.arange(n))     # exactly once
    cap = int(np.ceil(n / S * (1.0 + graph.KMEANS_CAP_SLACK)))
    assert max(len(p) for p in parts) <= cap, \
        "capacity rounding must bound every shard by ceil(n/S * (1+eps))"
    assert min(len(p) for p in parts) >= 1


def test_kmeans_partition_deterministic_in_seed():
    n, S = 500, 4
    data = _clustered(n, seed=3)
    a = graph.shard_assignment(n, S, assignment="kmeans", seed=0, data=data)
    b = graph.shard_assignment(n, S, assignment="kmeans", seed=0, data=data)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    c = graph.shard_assignment(n, S, assignment="kmeans", seed=1, data=data)
    assert any(not np.array_equal(pa, pc) for pa, pc in zip(a, c)), \
        "different seeds should explore different initializations"


def test_kmeans_no_empty_shard_under_duplicates():
    """n >> S with only 3 distinct vectors: duplicate centroids starve
    shards mid-rounding; the deterministic repair must still hand every
    shard >= 1 member (an empty shard has no entry point)."""
    n, S = 256, 8
    r = np.random.default_rng(5)
    base = r.normal(size=(3, 8)).astype(np.float32)
    data = jnp.asarray(base[r.integers(0, 3, n)])
    parts = graph.shard_assignment(n, S, assignment="kmeans", data=data)
    sizes = [len(p) for p in parts]
    assert min(sizes) >= 1, sizes
    assert sum(sizes) == n
    assert max(sizes) <= int(np.ceil(n / S * (1.0 + graph.KMEANS_CAP_SLACK)))


def test_kmeans_requires_data():
    with pytest.raises(ValueError, match="kmeans"):
        graph.shard_assignment(100, 4, assignment="kmeans")


def test_partition_stores_centroids_for_all_assignments():
    data, _ = _dataset(120, b=1, seed=6)
    for assignment in graph.ASSIGNMENTS:
        sg = graph.partition(data, 3, assignment=assignment, degree=8)
        cents = np.asarray(sg.centroids)
        assert cents.shape == (3, 16)
        assert np.all(np.isfinite(cents))
        if assignment == "kmeans":
            # Lloyd centroids (the statistic the placement optimized), not
            # member means — each shard's members must be closer to their
            # own centroid than the mean of the other shards' distances
            d = ((np.asarray(data)[:, None, :] - cents[None]) ** 2).sum(-1)
            for s in range(3):
                part = np.asarray(sg.global_ids[s][:int(sg.counts[s])])
                others = [t for t in range(3) if t != s]
                assert d[part, s].mean() < d[part][:, others].mean()
        else:
            for s in range(3):
                part = np.asarray(sg.global_ids[s][:int(sg.counts[s])])
                np.testing.assert_allclose(
                    cents[s], np.asarray(data)[part].mean(axis=0),
                    rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Routed search degeneracy + validation (DESIGN.md §13; full differential
# parity against the NumPy routing oracle lives in tests/test_oracle.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["dense", "hash"])
def test_routed_p_equals_S_bit_identical_to_scatter_gather(impl):
    """The degeneracy pin: p = S routes every query to every shard and
    must return byte-identical pools AND counters to routed_shards=None
    (it dispatches the same scatter-gather program)."""
    data, queries = _dataset(600, b=16, seed=12)
    sg = graph.partition(data, 4, assignment="kmeans", degree=10)
    full = search.sharded_knn_search(sg, queries, 8, 24, visited_impl=impl)
    same = search.sharded_knn_search(sg, queries, 8, 24, visited_impl=impl,
                                     routed_shards=4)
    np.testing.assert_array_equal(np.asarray(full.pool_ids),
                                  np.asarray(same.pool_ids))
    np.testing.assert_array_equal(np.asarray(full.pool_dist),
                                  np.asarray(same.pool_dist))
    assert int(full.n_computed) == int(same.n_computed)
    assert int(full.n_fresh) == int(same.n_fresh)
    assert int(full.hops) == int(same.hops)


def test_routed_does_less_work_and_masks_padding():
    """p < S: counters count routed work only (strictly less than the
    scatter-gather totals), and row-masked queries return INVALID pools
    without perturbing the routed queries' results."""
    data, queries = _dataset(600, b=16, seed=14)
    sg = graph.partition(data, 4, assignment="kmeans", degree=10)
    full = search.sharded_knn_search(sg, queries, 8, 24)
    routed = search.sharded_knn_search(sg, queries, 8, 24, routed_shards=2)
    assert int(routed.n_computed) < int(full.n_computed)
    assert bool(jnp.all(routed.pool_ids != INVALID))
    mask = jnp.zeros(16, bool).at[:5].set(True)
    masked = search.sharded_knn_search(sg, queries, 8, 24, routed_shards=2,
                                       row_mask=mask)
    assert bool(jnp.all(masked.pool_ids[5:] == INVALID))
    np.testing.assert_array_equal(np.asarray(masked.pool_ids[:5]),
                                  np.asarray(routed.pool_ids[:5]))


def test_routed_validates():
    import dataclasses
    data, queries = _dataset(200, b=8, seed=15)
    sg = graph.partition(data, 4, degree=8)
    for bad in (0, 5, -1):
        with pytest.raises(ValueError, match="routed_shards"):
            search.sharded_knn_search(sg, queries, 4, 8, routed_shards=bad)
    legacy = dataclasses.replace(sg, centroids=None)
    with pytest.raises(ValueError, match="centroids"):
        search.sharded_knn_search(legacy, queries, 4, 8, routed_shards=2)
    # p = S on a legacy graph is fine: it never consults centroids
    search.sharded_knn_search(legacy, queries, 4, 8, routed_shards=4)


def test_partition_flat_ids_block_diagonal():
    """The fused routed path's precondition (DESIGN.md §13): the stacked-
    flat adjacency must keep every edge inside its own shard's row range
    (a cross-shard edge would let one routed row silently search another
    shard) and must leave padding rows both edge-free and unreferenced."""
    data, _ = _dataset(300, b=1, seed=22)
    sg = graph.partition(data, 3, assignment="kmeans", degree=8)
    flat = np.asarray(sg.flat_ids)
    n_s = sg.shard_rows
    assert flat.shape == (3 * n_s, sg.max_degree)
    for s in range(3):
        c = int(sg.counts[s])
        rows = flat[s * n_s:(s + 1) * n_s]
        real = rows[rows != INVALID]
        assert ((real >= s * n_s) & (real < s * n_s + c)).all()
        assert (rows[c:] == INVALID).all()          # padding has no edges
        # flat rows are exactly the local rows shifted by the shard base
        local = np.asarray(sg.ids[s])
        np.testing.assert_array_equal(
            rows, np.where(local != INVALID, local + s * n_s, INVALID))


@pytest.mark.parametrize("impl", ["dense", "hash"])
@pytest.mark.parametrize("p", [1, 2, 3])
def test_routed_fused_path_matches_mesh_path(impl, p):
    """Packed-mesh dispatch (DESIGN.md §13): when a mesh slot holds more
    than one shard, routing runs as ONE beam search over the block-
    diagonal flat graph.  Same routed pairs, same per-row searches, same
    ascending-shard fold — so pools AND counters must come back byte-
    identical to the shard_map routed path (forced here via a 1-device
    mesh vs the 4-device placement mesh)."""
    import dataclasses
    data, queries = _dataset(600, b=16, seed=21)
    sg = graph.partition(data, 4, assignment="kmeans", degree=10)
    # same partition (deterministic in seed) committed to a packed 1-device
    # mesh: its placement makes sharded_knn_search pick the fused program
    mesh1 = sharding_lib.search_mesh(4, devices=jax.devices()[:1])
    sg1 = graph.partition(data, 4, assignment="kmeans", degree=10,
                          mesh=mesh1)
    assert sg1.flat_ids is not None
    via_mesh = search.sharded_knn_search(
        sg, queries, 8, 24, visited_impl=impl, routed_shards=p)
    fused = search.sharded_knn_search(
        sg1, queries, 8, 24, visited_impl=impl, routed_shards=p)
    np.testing.assert_array_equal(np.asarray(via_mesh.pool_ids),
                                  np.asarray(fused.pool_ids))
    np.testing.assert_array_equal(np.asarray(via_mesh.pool_dist),
                                  np.asarray(fused.pool_dist))
    assert int(via_mesh.n_computed) == int(fused.n_computed)
    assert int(via_mesh.n_fresh) == int(fused.n_fresh)
    assert int(via_mesh.hops) == int(fused.hops)
    # a pre-flat_ids graph on a packed mesh falls back to the shard_map
    # program: slower, never wrong
    legacy = dataclasses.replace(sg1, flat_ids=None)
    fb = search.sharded_knn_search(
        legacy, queries, 8, 24, visited_impl=impl, routed_shards=p)
    np.testing.assert_array_equal(np.asarray(via_mesh.pool_ids),
                                  np.asarray(fb.pool_ids))


def test_routed_fused_path_masks_rows():
    """Row masking on the fused path: masked queries return INVALID pools,
    stay out of the counters, and don't perturb unmasked queries."""
    data, queries = _dataset(600, b=16, seed=14)
    mesh1 = sharding_lib.search_mesh(4, devices=jax.devices()[:1])
    sg = graph.partition(data, 4, assignment="kmeans", degree=10,
                         mesh=mesh1)
    routed = search.sharded_knn_search(sg, queries, 8, 24, routed_shards=2)
    mask = jnp.zeros(16, bool).at[:5].set(True)
    masked = search.sharded_knn_search(sg, queries, 8, 24, routed_shards=2,
                                       row_mask=mask)
    assert bool(jnp.all(masked.pool_ids[5:] == INVALID))
    assert int(masked.n_computed) < int(routed.n_computed)
    np.testing.assert_array_equal(np.asarray(masked.pool_ids[:5]),
                                  np.asarray(routed.pool_ids[:5]))


def test_induced_partition_drops_only_cross_shard_edges():
    data, _ = _dataset(80, b=1, seed=4)
    adj, _ = knng.build_knng(data, 8)
    sg = graph.partition(data, 2, graph_ids=adj)
    adj_np = np.asarray(adj)
    for s in range(2):
        part = np.asarray(sg.global_ids[s][:int(sg.counts[s])])
        in_shard = np.isin(adj_np[part], part)
        local = np.asarray(sg.ids[s][:int(sg.counts[s])])
        # kept edge count matches the in-shard edge count, and every kept
        # edge maps back to the original global neighbor
        assert (local != INVALID).sum() == in_shard.sum()
        restored = np.where(local == INVALID, INVALID,
                            part[np.maximum(local, 0)])
        np.testing.assert_array_equal(
            restored[in_shard], adj_np[part][in_shard])


def test_mesh_with_multiple_shards_per_device_matches_serial():
    """4 shards on a 2-device mesh: each mesh slot folds its 2 local
    shards, then slots merge — the tree fold must still equal the serial
    shard-order fold (pool-wins ties make the reduction order-stable)."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (conftest forces 4)")
    data, queries = _dataset(600, b=16, seed=8)
    mesh = sharding_lib.search_mesh(4, devices=jax.devices()[:2])
    assert mesh.shape["shard"] == 2
    sg = graph.partition(data, 4, degree=10, mesh=mesh)
    # search defaults to the placement mesh — no mesh= needed
    res = search.sharded_knn_search(sg, queries, 8, 24)
    ri, rd, nf, nc, hp = serial_scatter_gather(sg, queries, 8, 24)
    np.testing.assert_array_equal(np.asarray(res.pool_ids), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(res.pool_dist), np.asarray(rd))
    assert (int(res.n_fresh), int(res.n_computed), int(res.hops)) == \
        (nf, nc, hp)


def test_search_mesh_adapts_to_device_count():
    for s in (1, 2, 4):
        mesh = sharding_lib.search_mesh(s)
        assert s % mesh.shape["shard"] == 0
        assert mesh.shape["shard"] <= len(jax.devices())
    mesh3 = sharding_lib.search_mesh(3, devices=jax.devices()[:2])
    assert mesh3.shape["shard"] == 1                 # 3 shards, 2 devices
    with pytest.raises(ValueError, match="num_shards"):
        sharding_lib.search_mesh(0)


# ---------------------------------------------------------------------------
# n=10k acceptance bars (nightly lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("metric", METRICS)
def test_chunked_exact_parity_10k(metric):
    """Acceptance: shards in {2, 4}, chunked disjoint partition, n=10k —
    the mesh path's merged pools match the serial decomposition exactly
    (ids byte-equal, dists byte-equal, counters psum-exact) under forced
    multi-device CPU, for all three metrics."""
    n, b, k, ef = 10_000, 32, 10, 32
    r = np.random.default_rng(17)
    data = jnp.asarray(r.normal(size=(n, 16)), jnp.float32)
    queries = data[r.integers(0, n, b)] + 0.1 * jnp.asarray(
        r.normal(size=(b, 16)), jnp.float32)
    adj = random_knng_ids(1, n, 16)
    for num_shards in (2, 4):
        sg = graph.partition(data, num_shards, assignment="chunked",
                             graph_ids=adj, metric=metric)
        _assert_mesh_matches_serial(sg, queries, k, ef, metric=metric)


@pytest.mark.slow
@pytest.mark.parametrize("metric", METRICS)
def test_random_partition_recall_10k(metric):
    """Acceptance: random partition at n=10k keeps recall@10 within 0.005
    of the unsharded search (per-shard exact-KNNG subindexes searched with
    the full ef each: scatter-gather explores more total candidates)."""
    n, b, k, ef, deg = 10_000, 32, 10, 64, 16
    r = np.random.default_rng(23)
    data = jnp.asarray(r.normal(size=(n, 16)), jnp.float32)
    queries = data[r.integers(0, n, b)] + 0.1 * jnp.asarray(
        r.normal(size=(b, 16)), jnp.float32)
    gt = evallib.ground_truth(data, queries, k, metric=metric)
    adj, _ = knng.build_knng(data, deg, metric=metric)
    base = search.knn_search(adj, data, queries, k, ef, 0, metric=metric)
    rec_base = evallib.recall_at_k(base.pool_ids, gt)
    sg = graph.partition(data, 4, assignment="random", degree=deg,
                         metric=metric)
    res = search.sharded_knn_search(sg, queries, k, ef, metric=metric)
    rec = evallib.recall_at_k(res.pool_ids, gt)
    assert rec >= rec_base - 0.005, (rec, rec_base)


@pytest.mark.slow
def test_routed_kmeans_recall_floor_10k():
    """Acceptance (ISSUE 7): kmeans partition, S=4, routed p=2 at n=10k —
    recall@10 within 0.01 of the unsharded search.  The corpus has mild
    cluster structure (the workload routing is FOR — on structureless
    isotropic noise no partition can put a neighborhood in < S shards,
    and no graph stays navigable once clusters fully separate): the
    partitioner puts each query's neighborhood in few shards, so the two
    centroid-nearest shards recover what scatter-gather finds in four."""
    n, b, k, ef, deg = 10_000, 32, 10, 64, 16
    r = np.random.default_rng(29)
    centers = r.normal(size=(8, 16)).astype(np.float32)   # unit-spread blobs
    data = jnp.asarray(
        centers[r.integers(0, 8, n)] + r.normal(size=(n, 16)), jnp.float32)
    queries = data[r.integers(0, n, b)] + 0.1 * jnp.asarray(
        r.normal(size=(b, 16)), jnp.float32)
    gt = evallib.ground_truth(data, queries, k)
    adj, _ = knng.build_knng(data, deg)
    base = search.knn_search(adj, data, queries, k, ef, 0)
    rec_base = evallib.recall_at_k(base.pool_ids, gt)
    assert rec_base > 0.9          # the baseline itself must be healthy
    sg = graph.partition(data, 4, assignment="kmeans", degree=deg)
    res = search.sharded_knn_search(sg, queries, k, ef, routed_shards=2)
    rec = evallib.recall_at_k(res.pool_ids, gt)
    assert rec >= rec_base - 0.01, (rec, rec_base)
