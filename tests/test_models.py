"""Per-arch smoke tests (required deliverable) + decode/forward parity.

Every assigned architecture instantiates its reduced same-family config,
runs one forward/train step on CPU, asserts output shapes and finiteness;
decoder-parity tests prove the KV-cache / recurrent decode path computes
the same function as the full forward (catches cache math bugs, incl. the
chunkwise mLSTM/mamba vs stepwise recurrence equivalence).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=16, seed=1):
    r = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(r.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(r.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.is_encdec:
        batch["enc_input"] = jnp.asarray(
            r.normal(size=(b, cfg.enc_seq, cfg.d_model)) * 0.05, jnp.float32)
    if cfg.vision_stub:
        batch["patches"] = jnp.asarray(
            r.normal(size=(b, cfg.n_patches, cfg.d_model)) * 0.05,
            jnp.float32)
    return batch


SLOW_ARCHS = {"jamba_v01_52b", "xlstm_350m"}    # 15-35s each on CPU


def _arch_params(ids):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS else a
            for a in ids]


@pytest.mark.parametrize("arch", _arch_params(registry.ARCH_IDS))
def test_arch_smoke_forward_and_step(arch):
    cfg = registry.get_config(arch).smoke()
    params = M.init_params(KEY, cfg)
    batch = _batch_for(cfg)
    logits = M.forward(params, cfg, batch["tokens"],
                       extras={k: v for k, v in batch.items()
                               if k not in ("tokens", "labels")})
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one SGD-ish step: loss + grad finite
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_cell_assignment_rules(arch):
    cfg = registry.get_config(arch)
    for sname, shape in SHAPES.items():
        ok, reason = registry.cell_is_runnable(cfg, shape)
        if sname != "long_500k":
            assert ok
        else:
            assert ok == cfg.sub_quadratic or not ok


@pytest.mark.parametrize("arch", _arch_params(
    ["granite_3_8b", "gemma2_9b", "xlstm_350m",
     "jamba_v01_52b", "grok_1_314b"]))
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training forward logits.

    MoE capacity is raised to the drop-free regime: GShard-style train-time
    drops (cap binds at T=24, never at decode T=2) are a known train/serve
    divergence, not a cache bug."""
    import dataclasses
    cfg = registry.get_config(arch).smoke()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(
            cfg.n_experts) / cfg.experts_per_tok)
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    r = np.random.default_rng(5)
    b, s = 2, 12
    toks = jnp.asarray(r.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full = M.forward(params, cfg, toks, remat=False)
    cache = M.init_cache(params, cfg, b, s + 2)
    outs = []
    for t in range(s):
        logits, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                      jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_whisper_decode_with_cross_attention():
    cfg = registry.get_config("whisper_small").smoke()
    params = M.init_params(KEY, cfg)
    batch = _batch_for(cfg)
    from repro.models.model import _encode
    memory = _encode(params, cfg, batch["enc_input"])
    full = M.forward(params, cfg, batch["tokens"],
                     extras={"enc_input": batch["enc_input"]}, remat=False)
    cache = M.init_cache(params, cfg, 2, 20)
    outs = []
    for t in range(8):
        logits, cache = M.decode_step(
            params, cfg, batch["tokens"][:, t:t + 1], cache, jnp.int32(t),
            extras={"enc_memory": memory})
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :8]),
                               rtol=2e-2, atol=2e-2)


def test_param_axes_trees_congruent():
    """The logical-axis tree must mirror the param tree for every arch."""
    for arch in registry.ARCH_IDS:
        cfg = registry.get_config(arch).smoke()
        params = jax.eval_shape(lambda: M.init_params(KEY, cfg))
        axes = M.param_axes(cfg)
        pleaves = jax.tree_util.tree_leaves_with_path(params)
        is_ax = lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t)
        aleaves = jax.tree_util.tree_leaves_with_path(axes, is_leaf=is_ax)
        ppaths = {jax.tree_util.keystr(p) for p, _ in pleaves}
        apaths = {jax.tree_util.keystr(p) for p, _ in aleaves}
        assert ppaths == apaths, (arch, ppaths ^ apaths)
        ranks = {jax.tree_util.keystr(p): len(l.shape) for p, l in pleaves}
        for p, ax in aleaves:
            assert len(ax) == ranks[jax.tree_util.keystr(p)], (arch, p, ax)


def test_param_count_matches_analytic():
    for arch in registry.ARCH_IDS:
        cfg = registry.get_config(arch)
        shapes = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        actual = sum(int(np.prod(l.shape)) for l in
                     jax.tree_util.tree_leaves(shapes))
        analytic = cfg.total_params()
        assert abs(actual - analytic) / actual < 0.05, (
            arch, actual, analytic)


def test_gemma2_softcap_and_window_active():
    cfg = registry.get_config("gemma2_9b")
    from repro.models.model import layer_plan
    plan = layer_plan(cfg)
    assert plan[0].window == cfg.window and plan[1].window == 0
    assert cfg.attn_softcap > 0 and cfg.logit_softcap > 0


def test_jamba_plan_1_to_7():
    cfg = registry.get_config("jamba_v01_52b")
    from repro.models.model import layer_plan
    plan = layer_plan(cfg)
    assert sum(1 for k in plan if k.mixer == "attn") == 1
    assert sum(1 for k in plan if k.mixer == "mamba") == 7
    assert sum(1 for k in plan if k.moe) == 4


def test_xlstm_plan_7_to_1():
    cfg = registry.get_config("xlstm_350m")
    from repro.models.model import layer_plan
    plan = layer_plan(cfg)
    assert sum(1 for k in plan if k.mixer == "mlstm") == 7
    assert sum(1 for k in plan if k.mixer == "slstm") == 1
