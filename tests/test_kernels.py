"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes (required deliverable)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metric as metric_lib
from repro.kernels import ops, ref


@pytest.fixture(autouse=True)
def _interpret_mode():
    """Force the Pallas interpret path for THIS module only (the env var is
    read per call; leaking it poisons model tests with pallas-JVP paths)."""
    old = os.environ.get("REPRO_PALLAS_INTERPRET")
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    yield
    if old is None:
        os.environ.pop("REPRO_PALLAS_INTERPRET", None)
    else:
        os.environ["REPRO_PALLAS_INTERPRET"] = old

SHAPES_L2 = [(8, 8, 4), (37, 91, 50), (128, 128, 128), (200, 65, 33),
             (1, 300, 960)]
DTYPES = [jnp.float32, jnp.bfloat16]
METRICS = ["l2", "ip", "cosine"]


@pytest.mark.parametrize("nq,nx,d", SHAPES_L2)
@pytest.mark.parametrize("dtype", DTYPES)
def test_l2_distance_matches_ref(nq, nx, d, dtype):
    r = np.random.default_rng(nq * 1000 + nx)
    q = jnp.asarray(r.normal(size=(nq, d)), dtype)
    x = jnp.asarray(r.normal(size=(nx, d)), dtype)
    out = ops.l2_distance(q, x)
    exp = ref.l2_distance_ref(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, exp, rtol=tol, atol=tol)
    assert out.dtype == jnp.float32
    assert bool(jnp.all(out >= 0.0))


@pytest.mark.parametrize("b,k,d", [(1, 1, 8), (9, 21, 33), (16, 128, 64),
                                   (5, 130, 17)])
def test_gather_distance_cache_semantics(b, k, d):
    r = np.random.default_rng(b * 100 + k)
    u = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    c = jnp.asarray(r.normal(size=(b, k, d)), jnp.float32)
    cached = jnp.asarray(r.normal(size=(b, k)), jnp.float32)
    mask = jnp.asarray(r.random((b, k)) > 0.5)
    out = ops.gather_distance(u, c, cached, mask)
    exp = ref.gather_distance_ref(u, c, cached, mask)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)
    # where mask is False the cached value must pass through bit-exactly
    np.testing.assert_array_equal(np.asarray(out)[~np.asarray(mask)],
                                  np.asarray(cached)[~np.asarray(mask)])


FA_CASES = [
    dict(sq=64, sk=64, w=0, cap=0.0, off=0, causal=True),
    dict(sq=32, sk=32, w=17, cap=0.0, off=0, causal=True),
    dict(sq=64, sk=64, w=0, cap=30.0, off=0, causal=True),
    dict(sq=1, sk=70, w=0, cap=0.0, off=69, causal=True),
    dict(sq=40, sk=56, w=0, cap=0.0, off=16, causal=True),
    dict(sq=24, sk=24, w=0, cap=0.0, off=0, causal=False),
    dict(sq=16, sk=144, w=48, cap=50.0, off=128, causal=True),
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_matches_ref(case, dtype):
    r = np.random.default_rng(case["sq"] * 7 + case["sk"])
    shape_q = (2, 3, case["sq"], 16)
    shape_k = (2, 3, case["sk"], 16)
    q = jnp.asarray(r.normal(size=shape_q), dtype)
    k = jnp.asarray(r.normal(size=shape_k), dtype)
    v = jnp.asarray(r.normal(size=shape_k), dtype)
    out = ops.flash_attention(q, k, v, causal=case["causal"],
                              window=case["w"], softcap=case["cap"],
                              q_offset=case["off"])
    exp = ref.flash_attention_ref(q, k, v, causal=case["causal"],
                                  window=case["w"], softcap=case["cap"],
                                  q_offset=case["off"])
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("sq,sk", [(64, 64), (128, 2100), (1, 3000)])
def test_chunked_attention_matches_ref(sq, sk):
    """The XLA flash path (dry-run lowering) must equal the dense ref."""
    r = np.random.default_rng(sq + sk)
    q = jnp.asarray(r.normal(size=(1, 2, sq, 32)), jnp.float32)
    k = jnp.asarray(r.normal(size=(1, 2, sk, 32)), jnp.float32)
    v = jnp.asarray(r.normal(size=(1, 2, sk, 32)), jnp.float32)
    off = sk - sq
    out = ref.flash_attention_chunked(q, k, v, causal=True, q_offset=off,
                                      chunk=256)
    exp = ref.flash_attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(out, exp, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nq,nx,d", [(8, 8, 4), (37, 91, 50), (200, 65, 33)])
@pytest.mark.parametrize("metric", METRICS)
def test_pairwise_distance_matches_ref(nq, nx, d, metric):
    """Interpret-mode Pallas kernel == jnp oracle for every metric."""
    r = np.random.default_rng(nq * 1000 + nx)
    q = jnp.asarray(r.normal(size=(nq, d)), jnp.float32)
    x = jnp.asarray(r.normal(size=(nx, d)), jnp.float32)
    out = ops.pairwise_distance(q, x, metric)
    met = metric_lib.resolve(metric)
    exp = ref.pairwise_distance_ref(met.prepare(q), met.prepare(x),
                                    met.kernel)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("b,k,d", [(1, 1, 8), (9, 21, 33), (5, 130, 17)])
@pytest.mark.parametrize("metric", METRICS)
def test_gather_distance_metrics(b, k, d, metric):
    r = np.random.default_rng(b * 100 + k)
    u = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    c = jnp.asarray(r.normal(size=(b, k, d)), jnp.float32)
    cached = jnp.asarray(r.normal(size=(b, k)), jnp.float32)
    mask = jnp.asarray(r.random((b, k)) > 0.5)
    out = ops.gather_distance(u, c, cached, mask, metric=metric)
    met = metric_lib.resolve(metric)
    exp = ref.gather_distance_ref(met.prepare(u), met.prepare(c), cached,
                                  mask, met.kernel)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)
    # V_delta pass-through must stay bit-exact regardless of metric
    np.testing.assert_array_equal(np.asarray(out)[~np.asarray(mask)],
                                  np.asarray(cached)[~np.asarray(mask)])


def _sq8_case(r, n, d):
    """Random corpus quantized through the metric seam (prepared space is
    the caller's job; these are raw kernel-form tests so prepare == id)."""
    x = jnp.asarray(r.normal(size=(n, d)) * 2.0, jnp.float32)
    return x, metric_lib.quantize_sq8(x)


@pytest.mark.parametrize("nq,nx,d", [(8, 8, 4), (37, 91, 50), (200, 65, 33),
                                     (128, 128, 128)])
@pytest.mark.parametrize("metric", METRICS)
def test_pairwise_sq8_bitmatches_ref(nq, nx, d, metric):
    """Interpret-mode int8 Pallas form == ref.py quantized oracle, BIT
    identical: same contraction shapes, same fp32 accumulation, padding
    contributes exact zeros (acceptance gate, DESIGN.md §16)."""
    r = np.random.default_rng(nq * 1000 + nx)
    q = jnp.asarray(r.normal(size=(nq, d)), jnp.float32)
    _, quant = _sq8_case(r, nx, d)
    met = metric_lib.resolve(metric)
    out = ops.pairwise_distance_q(q, quant, metric)
    exp = ref.pairwise_distance_sq8_ref(met.prepare(q), quant.codes,
                                        quant.scale, quant.norms,
                                        met.kernel)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("b,k,d", [(1, 1, 8), (9, 21, 33), (5, 130, 17),
                                   (8, 128, 128)])
@pytest.mark.parametrize("metric", METRICS)
def test_gather_sq8_matches_ref(b, k, d, metric):
    """Interpret-mode gathered int8 form == ref.py oracle to fp32 dot
    tolerance (the tile's (bk, d) gemm and the ref's batched dot_general
    lower to different accumulation groupings on CPU — same contract as
    the fp32 gather kernel), with V_delta pass-through bit-exact."""
    r = np.random.default_rng(b * 100 + k)
    u = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    _, quant = _sq8_case(r, 64, d)
    gidx = jnp.asarray(r.integers(0, 64, size=(b, k)))
    codes = quant.codes[gidx]
    cnorms = quant.norms[gidx]
    cached = jnp.asarray(r.normal(size=(b, k)), jnp.float32)
    mask = jnp.asarray(r.random((b, k)) > 0.5)
    met = metric_lib.resolve(metric)
    out = ops.gather_distance_q(u, codes, quant.scale, cnorms, cached,
                                mask, metric)
    exp = ref.gather_distance_sq8_ref(met.prepare(u), codes, quant.scale,
                                      cnorms, cached, mask, met.kernel)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-4)
    # where mask is False the cached value must pass through bit-exactly
    np.testing.assert_array_equal(np.asarray(out)[~np.asarray(mask)],
                                  np.asarray(cached)[~np.asarray(mask)])


def test_sq8_memory_footprint():
    """The point of sq8: int8 codes are 4x smaller than the fp32 corpus
    (scale + norms overhead is O(d + n), negligible at scale)."""
    r = np.random.default_rng(9)
    x, quant = _sq8_case(r, 512, 128)
    assert quant.codes.dtype == jnp.int8
    assert quant.codes.nbytes * 4 == x.nbytes


def test_l2_metric_is_the_pre_refactor_default():
    """metric="l2" must be BIT-IDENTICAL to the metric-less entry points
    (regression guard for the metric refactor)."""
    r = np.random.default_rng(42)
    q = jnp.asarray(r.normal(size=(33, 24)), jnp.float32)
    x = jnp.asarray(r.normal(size=(57, 24)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.pairwise_distance(q, x, "l2")),
        np.asarray(ops.l2_distance(q, x)))
    np.testing.assert_array_equal(
        np.asarray(ref.pairwise_distance_ref(q, x, "l2")),
        np.asarray(ref.l2_distance_ref(q, x)))
    u = q[:5]
    c = x[:15].reshape(5, 3, 24)
    np.testing.assert_array_equal(
        np.asarray(ops.gather_distance(u, c, metric="l2")),
        np.asarray(ops.gather_distance(u, c)))


def test_ip_distance_is_affine_in_similarity():
    """d = 1 - <q, x> exactly (the monotone similarity->distance map)."""
    r = np.random.default_rng(3)
    q = jnp.asarray(r.normal(size=(16, 12)), jnp.float32)
    x = jnp.asarray(r.normal(size=(20, 12)), jnp.float32)
    out = ops.pairwise_distance(q, x, "ip")
    np.testing.assert_allclose(out, 1.0 - q @ x.T, rtol=1e-5, atol=1e-5)


def test_cosine_distance_bounds_and_self():
    r = np.random.default_rng(4)
    x = jnp.asarray(r.normal(size=(30, 9)), jnp.float32)
    d = ops.pairwise_distance(x, x, "cosine")
    assert bool(jnp.all(d >= -1e-5)) and bool(jnp.all(d <= 2.0 + 1e-5))
    np.testing.assert_allclose(jnp.diagonal(d), 0.0, atol=1e-5)
