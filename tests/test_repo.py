"""Repo hygiene (tools/check_repo.py): compiled-Python artifacts and
runtime index snapshots (serve/resilience.py) must never be tracked —
.gitignore can't evict a file that was force-added."""
import importlib.util
import pathlib

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "check_repo", _ROOT / "tools" / "check_repo.py")
check_repo = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_repo)


@pytest.mark.parametrize("path,bad", [
    ("src/repro/core/__pycache__/build.cpython-311.pyc", True),
    ("__pycache__/x.pyc", True),
    ("a/b/c.pyo", True),
    ("src/repro/core/build.py", False),
    ("docs/__pycache__.md", False),          # only real path segments count
    ("notes/pycache.txt", False),
    ("snaps/index.snapshot.npz", True),      # runtime serving state
    ("index.snapshot.json", True),
    ("data/corpus.npz", False),              # plain npz data is fine
    ("docs/snapshot.md", False),
    ("wal/index-g0.wal", True),              # streaming mutation state
    ("index-g2.stream.npz", True),
    ("serve/index.stream.json", True),
    ("notes/wal.md", False),                 # suffix, not substring
    ("src/stream.py", False),
])
def test_is_artifact(path, bad):
    assert check_repo.is_artifact(path) is bad


def test_snapshot_suffixes_match_resilience():
    """The tool's hardcoded suffixes must track serve/resilience.py's
    constants (the tool can't import repro — it runs dependency-free)."""
    from repro.serve import resilience
    assert set(check_repo.SNAPSHOT_SUFFIXES) == {
        resilience.SNAPSHOT_NPZ, resilience.SNAPSHOT_MANIFEST}


def test_stream_suffixes_match_streaming():
    """Same sync rule for the streaming-index runtime suffixes (WAL,
    external-id sidecar, generation pointer — serve/streaming.py)."""
    from repro.serve import streaming
    assert set(check_repo.STREAM_SUFFIXES) == set(streaming.STREAM_SUFFIXES)


def test_no_tracked_bytecode():
    try:
        bad = check_repo.tracked_artifacts(_ROOT)
    except Exception as e:                     # no git in the sandbox
        pytest.skip(f"git ls-files unavailable: {e}")
    assert bad == [], f"tracked __pycache__/.pyc files: {bad}"
