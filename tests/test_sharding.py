"""Sharding rules: divisibility fallback, axis dedup, pod filtering.

Runs on the 1-CPU container by constructing *abstract* meshes (jax.make_mesh
requires real devices, so rules are tested through spec_for with a mesh
shape stand-in)."""
import types

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_maps_to_pod_data_on_multi():
    spec = sh.spec_for((512, 4096), ("batch", None), MULTI,
                       sh.DEFAULT_RULES)
    assert spec == P(("pod", "data"), None)


def test_batch_drops_pod_on_single():
    spec = sh.spec_for((256, 4096), ("batch", None), SINGLE,
                       sh.DEFAULT_RULES)
    assert spec == P("data", None)


def test_indivisible_dim_replicates():
    # 56 heads % 16 != 0 -> heads dim replicated, head_dim takes model
    spec = sh.spec_for((7168, 56, 128), ("mlp_in", "heads", "head_dim"),
                       SINGLE, sh.DEFAULT_RULES)
    assert spec == P("data", None, "model")


def test_divisible_heads_take_model_and_head_dim_backs_off():
    spec = sh.spec_for((4096, 32, 128), ("mlp_in", "heads", "head_dim"),
                       SINGLE, sh.DEFAULT_RULES)
    assert spec == P("data", "model", None)


def test_axis_never_used_twice():
    # batch takes (pod,data); kv_seq wants data -> must back off
    spec = sh.spec_for((128, 32768, 8, 128),
                       ("batch", "kv_seq", "kv_heads", "head_dim"),
                       MULTI, sh.DEFAULT_RULES)
    assert spec[0] == ("pod", "data")
    assert spec[1] is None
    assert spec[3] == "model"


def test_long_context_cache_shards_seq():
    # batch=1 unshardable -> kv_seq gets the data axis (long_500k layout)
    spec = sh.spec_for((1, 524288, 8, 224),
                       ("batch", "kv_seq", "kv_heads", "head_dim"),
                       SINGLE, sh.DEFAULT_RULES)
    assert spec == P(None, "data", None, "model")


def test_expert_sharding_and_fallback():
    # arctic: 128 experts over 16-way model axis
    spec = sh.spec_for((128, 7168, 4864), ("expert", "mlp_in", "mlp"),
                       SINGLE, sh.DEFAULT_RULES)
    assert spec == P("model", "data", None)
    # grok: 8 experts < 16 -> replicate experts, shard nothing else on model
    spec = sh.spec_for((8, 6144, 32768), ("expert", "mlp_in", "mlp"),
                       SINGLE, sh.DEFAULT_RULES)
    assert spec == P(None, "data", "model")


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = sh.constrain(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_all_archs_have_tp_shardable_head_dim():
    """Every assigned arch can TP-shard attention via head_dim (the rule
    the dry-run relies on when head counts don't divide 16)."""
    from repro.configs import registry
    for arch in registry.ARCH_IDS:
        cfg = registry.get_config(arch)
        assert cfg.head_dim % 16 == 0, (arch, cfg.head_dim)
        assert cfg.d_ff == 0 or cfg.d_ff % 16 == 0
