"""Checkpoint + fault tolerance: roundtrip, retention, resume equivalence,
failure-injection recovery, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.train import checkpoint as ck
from repro.train import data as data_lib
from repro.train import fault_tolerance as ft
from repro.train import train_loop
from repro.train.optimizer import AdamWConfig


def _tiny_setup(seed=0):
    import dataclasses
    cfg = registry.get_config("granite_3_8b").smoke()
    cfg = dataclasses.replace(cfg, vocab=32, n_layers=1, d_model=32,
                              d_ff=64, n_heads=2, n_kv_heads=2, d_head=16)
    dcfg = data_lib.DataConfig(vocab=32, seq_len=16, global_batch=4,
                               seed=seed)
    ds = data_lib.SyntheticLM(dcfg)
    opt = AdamWConfig(lr=1e-3)
    scfg = train_loop.StepConfig(compute_dtype="float32", remat=False)
    state = train_loop.init_state(jax.random.PRNGKey(seed), cfg, opt, scfg)
    step = jax.jit(train_loop.make_train_step(cfg, opt, scfg))
    return state, step, ds


def test_roundtrip_bit_exact(tmp_path):
    state, step, ds = _tiny_setup()
    state, _ = step(state, ds.global_batch(0))
    ck.save(str(tmp_path), 1, state)
    restored, got_step = ck.restore(str(tmp_path), state)
    assert got_step == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention(tmp_path):
    state, _, _ = _tiny_setup()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, {"x": jnp.ones(3) * s}, keep=2)
    assert ck.list_steps(str(tmp_path)) == [4, 5]


def test_no_torn_tmp_files(tmp_path):
    ck.save(str(tmp_path), 1, {"x": jnp.ones(3)})
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_resume_equivalence(tmp_path):
    """5 straight steps == 3 steps + ckpt + restore + 2 steps, bit-for-bit
    (deterministic pipeline + atomic checkpoints)."""
    state_a, step, ds = _tiny_setup(seed=4)
    for s in range(5):
        state_a, _ = step(state_a, ds.global_batch(s))

    state_b, step2, ds2 = _tiny_setup(seed=4)
    for s in range(3):
        state_b, _ = step2(state_b, ds2.global_batch(s))
    ck.save(str(tmp_path), 3, state_b)
    restored, at = ck.restore(str(tmp_path), state_b)
    for s in range(at, 5):
        restored, _ = step2(restored, ds2.global_batch(s))
    np.testing.assert_allclose(
        np.asarray(jax.tree_util.tree_leaves(state_a.params)[0]),
        np.asarray(jax.tree_util.tree_leaves(restored.params)[0]),
        rtol=1e-6, atol=1e-7)


def test_run_resumable_with_failures(tmp_path):
    """Injected failures recover from the latest checkpoint and reach the
    same final state as an uninterrupted run."""
    state, step, ds = _tiny_setup(seed=9)
    fails = {4, 7}

    def injector(s):
        if s in fails:
            fails.discard(s)
            return True
        return False

    final, steps, restarts = ft.run_resumable(
        state, step, lambda s: ds.global_batch(s), n_steps=10,
        ckpt_dir=str(tmp_path), ckpt_every=2, fail_injector=injector)
    assert steps == 10 and restarts == 2

    clean, *_ = ft.run_resumable(
        state, step, lambda s: ds.global_batch(s), n_steps=10,
        ckpt_dir=str(tmp_path) + "_clean", ckpt_every=100)
    np.testing.assert_allclose(
        np.asarray(jax.tree_util.tree_leaves(final.params)[0]),
        np.asarray(jax.tree_util.tree_leaves(clean.params)[0]),
        rtol=1e-6, atol=1e-7)


def test_heartbeat_monitor():
    mon = ft.HeartbeatMonitor(["w0", "w1"], timeout_s=10)
    mon.beat("w0", at=100.0)
    mon.beat("w1", at=100.0)
    assert mon.dead_workers(now=105.0) == []
    mon.beat("w0", at=111.0)
    assert mon.dead_workers(now=115.0) == ["w1"]


def test_straggler_mitigator():
    sm = ft.StragglerMitigator(tolerance=2.0)
    for _ in range(10):
        assert not sm.record(1.0)
    assert sm.record(5.0)           # 5x median: flagged
    assert not sm.record(1.1)
    assert sm.deadline() == pytest.approx(2.0, rel=0.2)


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint written under one layout restores into another template
    (device-count change simulated by a fresh state object)."""
    state, step, ds = _tiny_setup(seed=2)
    state, _ = step(state, ds.global_batch(0))
    ck.save(str(tmp_path), 1, state)
    template, _, _ = _tiny_setup(seed=2)        # fresh arrays, same tree
    restored, s = ft.elastic_reshard(str(tmp_path), template)
    assert s == 1
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(state.params)[0]),
        np.asarray(jax.tree_util.tree_leaves(restored.params)[0]))


def test_save_async(tmp_path):
    fut = ck.save_async(str(tmp_path), 7, {"x": jnp.arange(5)})
    fut.result(timeout=30)
    assert ck.latest_step(str(tmp_path)) == 7
