"""Hash-set search state (DESIGN.md §9): collision handling, dense/hash
parity, counter contract, and the k > ef guard."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashset, knng, search
from repro.core.graph import INVALID, random_knng_ids

METRICS = ["l2", "ip", "cosine"]


def _colliding_keys(slots: int, count: int, target: int = 0) -> np.ndarray:
    """``count`` distinct non-negative ids sharing one home slot."""
    cand = np.arange(200_000, dtype=np.int32)
    home = np.asarray(hashset.home_slot(jnp.asarray(cand), slots))
    keys = cand[home == target][:count]
    assert len(keys) == count
    return keys


# ---------------------------------------------------------------------------
# hash-table primitive
# ---------------------------------------------------------------------------

def test_lookup_insert_adversarial_collisions():
    """Keys all hashing to one slot probe past each other: insert + find."""
    slots = 32
    keys = jnp.asarray(_colliding_keys(slots, 8))[None, :]
    act = jnp.ones(keys.shape, bool)
    tab = hashset.make_tables((1,), slots)
    tab, found, ins = hashset.lookup_insert(tab, keys, act)
    assert not bool(found.any()) and bool(ins.all())
    tab, found2, ins2 = hashset.lookup_insert(tab, keys, act)
    assert bool(found2.all()) and not bool(ins2.any())


def test_lookup_insert_overflow_no_false_positives():
    """A full table drops inserts (false negatives) but never reports a
    key it does not hold — the contract search correctness rests on."""
    slots = 8
    keys = jnp.arange(0, 2 * slots, dtype=jnp.int32)[None, :]
    act = jnp.ones(keys.shape, bool)
    tab = hashset.make_tables((1,), slots)
    tab, found, ins = hashset.lookup_insert(tab, keys, act)
    assert not bool(found.any())
    assert int(ins.sum()) == slots                 # table exactly full
    # fresh keys never inserted: membership must come back False
    probe = jnp.arange(100, 100 + 2 * slots, dtype=jnp.int32)[None, :]
    _, found_new, ins_new = hashset.lookup_insert(tab, probe, act)
    assert not bool(found_new.any()) and not bool(ins_new.any())
    # re-lookup of the original keys: found iff previously inserted
    _, found3, _ = hashset.lookup_insert(tab, keys, act)
    np.testing.assert_array_equal(np.asarray(found3), np.asarray(ins))


def test_lookup_insert_wide_rows_use_sort_rank_path():
    """K > RUN_RANK_TRI_MAX exercises _run_rank's stable-sort path — the
    one serving configs with W·Mx > 128 rely on for race-free inserts."""
    K = hashset.RUN_RANK_TRI_MAX + 72
    slots = 2048
    r = np.random.default_rng(9)
    keys = jnp.asarray(r.choice(100_000, size=K, replace=False),
                       jnp.int32)[None, :]
    act = jnp.ones(keys.shape, bool)
    tab = hashset.make_tables((1,), slots)
    tab, found, ins = hashset.lookup_insert(tab, keys, act)
    assert not bool(found.any())
    # load factor < 1/8: every distinct key must land
    assert bool(ins.all())
    stored = np.asarray(tab)
    assert len(set(stored[stored != hashset.EMPTY])) == K, "lost inserts"
    _, found2, ins2 = hashset.lookup_insert(tab, keys, act)
    assert bool(found2.all()) and not bool(ins2.any())


def test_run_rank_sort_path_matches_bruteforce():
    """Both _run_rank materializations equal the flat-order oracle."""
    r = np.random.default_rng(4)
    for K in (17, hashset.RUN_RANK_TRI_MAX + 33):
        vals = r.integers(0, 9, size=(3, K))
        got = np.asarray(hashset._run_rank(jnp.asarray(vals, jnp.int32)))
        exp = np.zeros_like(vals)
        for b in range(vals.shape[0]):
            for i in range(K):
                exp[b, i] = int(np.sum(vals[b, :i] == vals[b, i]))
        np.testing.assert_array_equal(got, exp)


def test_lookup_insert_inactive_lanes_untouched():
    tab = hashset.make_tables((2,), 16)
    keys = jnp.array([[3, 5], [7, 9]], jnp.int32)
    act = jnp.array([[True, False], [False, True]])
    tab, found, ins = hashset.lookup_insert(tab, keys, act)
    np.testing.assert_array_equal(np.asarray(ins), np.asarray(act))
    stored = set(np.asarray(tab).ravel().tolist()) - {hashset.EMPTY}
    assert stored == {3, 9}


# ---------------------------------------------------------------------------
# search parity dense vs hash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", METRICS)
def test_hash_parity_10k(metric):
    """Acceptance: hash top-k matches dense on >= 99% of queries at n=10k.

    Graph quality is irrelevant to parity, so a deterministic random
    regular graph stands in for a built index (10k-node builds would
    dominate suite runtime)."""
    n, d, b, k, ef = 10_000, 16, 64, 10, 32
    r = np.random.default_rng(3)
    data = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    queries = data[:b] + 0.1 * jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    adj = random_knng_ids(1, n, 16)
    dense = search.knn_search(adj, data, queries, k, ef, 0, metric=metric)
    hashed = search.knn_search(adj, data, queries, k, ef, 0, metric=metric,
                               visited_impl="hash")
    same = np.asarray(
        (dense.pool_ids == hashed.pool_ids).all(axis=-1))
    assert same.mean() >= 0.99
    # auto sizing keeps the table load <= 1/2; on this workload no insert
    # is dropped, so the counters agree exactly — pinning DESIGN.md §9.3's
    # equality-in-expectation as a regression check for this data
    assert int(hashed.n_computed) == int(dense.n_computed)
    assert int(hashed.n_fresh) == int(dense.n_fresh)


def test_hash_eso_cache_pure_optimization(small_dataset):
    """Hash-mode V_delta stays a pure optimization: identical pools with
    and without sharing, and identical-graph sharing halves #computed."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    g2 = jnp.stack([adj, adj])
    b = 8
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.ones((b,), bool)
    ef = jnp.array([15, 15], jnp.int32)
    ep = jnp.zeros((b, 2), jnp.int32)
    kw = dict(ef_max=15, max_hops=60, visited_impl="hash")
    r1 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=True, **kw)
    r2 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=False, **kw)
    np.testing.assert_array_equal(np.asarray(r1.pool_ids),
                                  np.asarray(r2.pool_ids))
    assert int(r1.n_computed) * 2 == int(r1.n_fresh)
    assert int(r2.n_computed) == int(r2.n_fresh)


def test_tiny_table_overflow_degrades_gracefully(small_dataset):
    """A deliberately undersized table forces overflow: results stay
    duplicate-free and match dense; only the #dist counters grow."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    dense = search.knn_search(adj, data, queries, 10, 30, 0)
    tiny = search.knn_search(adj, data, queries, 10, 30, 0,
                             visited_impl="hash", hash_slots=16)
    for row in np.asarray(tiny.pool_ids):
        real = [x for x in row.tolist() if x >= 0]
        assert len(real) == len(set(real)), "duplicate ids in pool"
    overlap = (tiny.pool_ids[:, :, None] == dense.pool_ids[:, None, :]
               ).any(-1).mean()
    assert float(overlap) >= 0.99
    assert int(tiny.n_computed) >= int(dense.n_computed)


# ---------------------------------------------------------------------------
# dense-mode counters unchanged (the paper-exact #dist contract)
# ---------------------------------------------------------------------------

def test_dense_counters_match_sequential_reference(small_dataset):
    """Dense-mode #dist equals the literal Algorithm 1 count per query —
    the refactor must not perturb the paper's cost accounting."""
    from test_search import kanns_python
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    adj_np, data_np = np.asarray(adj), np.asarray(data)
    for qi in range(6):
        res = search.knn_search(adj, data, queries[qi:qi + 1], 5, 20, 0)
        _, n_ref = kanns_python(adj_np, data_np, np.asarray(queries[qi]),
                                20, 0)
        assert int(res.n_computed) == n_ref
        assert int(res.n_fresh) == n_ref


def test_knn_search_rejects_k_greater_than_ef(small_dataset):
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    with pytest.raises(ValueError, match="k=12 > ef=8"):
        search.knn_search(adj, data, queries, 12, 8, 0)
