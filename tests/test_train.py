"""Training substrate: optimizer, microbatch equivalence, compression,
end-to-end loss decrease on the synthetic Markov LM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.train import compression, data as data_lib, train_loop
from repro.train.optimizer import AdamWConfig, adamw, schedule_lr


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, schedule="constant")
    init, update = adamw(cfg)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5, abs=0.01)
    assert lrs[2] == pytest.approx(1.0, abs=0.05)
    assert lrs[3] < lrs[2] and lrs[4] < 0.05


def test_grad_clip():
    from repro.train.optimizer import clip_by_global_norm
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    n2 = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(n2) == pytest.approx(1.0, rel=1e-5)


def test_microbatch_equivalence():
    """mb=1 and mb=4 produce the same update (fp32 tolerance)."""
    cfg = registry.get_config("granite_3_8b").smoke()
    key = jax.random.PRNGKey(0)
    opt = AdamWConfig(lr=1e-3)
    r = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (8, 16))),
             "labels": jnp.asarray(r.integers(0, cfg.vocab, (8, 16)))}
    outs = []
    for mb in (1, 4):
        scfg = train_loop.StepConfig(microbatches=mb,
                                     compute_dtype="float32", remat=False)
        state = train_loop.init_state(key, cfg, opt, scfg)
        step = train_loop.make_train_step(cfg, opt, scfg)
        new, metrics = step(state, batch)
        outs.append((float(metrics["loss"]),
                     np.asarray(jax.tree_util.tree_leaves(new.params)[0])))
    assert outs[0][0] == pytest.approx(outs[1][0], rel=1e-4)
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-4, atol=1e-5)


def test_int8_error_feedback_roundtrip():
    r = np.random.default_rng(0)
    g = {"w": jnp.asarray(r.normal(size=(64, 64)), jnp.float32)}
    ef = compression.init_ef(g)
    qs, ef = compression.compress_int8_ef(g, ef)
    deq = compression.decompress_int8(qs)
    err = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert err <= scale * 0.51 + 1e-6
    # residual holds exactly the quantization error
    np.testing.assert_allclose(np.asarray(ef.residual["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)


def test_int8_ef_preserves_signal_over_steps():
    """With a constant gradient, EF-compressed updates average to it."""
    g = {"w": jnp.asarray([[0.003, -1.7, 0.42, 7e-4]], jnp.float32)}
    ef = compression.init_ef(g)
    acc = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        qs, ef = compression.compress_int8_ef(g, ef)
        acc = acc + compression.decompress_int8(qs)["w"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               rtol=0.05, atol=2e-4)


def test_topk_sparsify():
    x = jnp.asarray(np.arange(100, dtype=np.float32))
    kept = compression.topk_sparsify(x, 0.1)
    assert int(jnp.sum(kept != 0)) == 10
    assert float(kept[-1]) == 99.0


def test_data_pipeline_determinism_and_sharding():
    cfg = data_lib.DataConfig(vocab=64, seq_len=32, global_batch=8, seed=3)
    ds = data_lib.SyntheticLM(cfg)
    b1 = ds.batch(step=7)
    b2 = ds.batch(step=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch(step=8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_training_learns_markov_structure():
    """A tiny LM trained on the synthetic pipeline beats the unigram
    baseline and approaches the source entropy floor."""
    cfg = registry.get_config("granite_3_8b").smoke()
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab=64, n_layers=2)
    dcfg = data_lib.DataConfig(vocab=64, seq_len=32, global_batch=16, seed=0)
    ds = data_lib.SyntheticLM(dcfg)
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=60,
                      weight_decay=0.0)
    scfg = train_loop.StepConfig(compute_dtype="float32", remat=False)
    state = train_loop.init_state(jax.random.PRNGKey(1), cfg, opt, scfg)
    step = jax.jit(train_loop.make_train_step(cfg, opt, scfg))
    losses = []
    for s in range(60):
        state, m = step(state, ds.global_batch(s))
        losses.append(float(m["loss"]))
    floor = data_lib.optimal_loss(dcfg)
    assert losses[-1] < losses[0] - 0.5
    assert losses[-1] < np.log(64) * 0.95      # beats uniform clearly
    assert losses[-1] > floor - 0.05           # no cheating below entropy
