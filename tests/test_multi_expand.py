"""Width-W multi-expansion search (DESIGN.md §10): top-k pool merge
byte-equivalence vs the pre-refactor argsort merge (adversarial ties /
INVALID padding), W=1 dense bit-identity end to end, and W>1 recall parity
at 10k across metrics and visited impls."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as evallib
from repro.core import knng, search
from repro.core.graph import INVALID, random_knng_ids

METRICS = ["l2", "ip", "cosine"]


def argsort_merge(pool_ids, pool_dist, expanded, cand_ids, cand_dist):
    """The pre-refactor merge: stable argsort over the full concatenation
    (pool first, candidates in flat order) — the byte-level oracle the
    top-k merge must reproduce."""
    ef_max = pool_ids.shape[-1]
    all_ids = jnp.concatenate([pool_ids, cand_ids], axis=-1)
    all_dist = jnp.concatenate([pool_dist, cand_dist], axis=-1)
    all_exp = jnp.concatenate(
        [expanded, jnp.zeros_like(cand_ids, bool)], axis=-1)
    order = jnp.argsort(all_dist, axis=-1)[..., :ef_max]
    return (jnp.take_along_axis(all_ids, order, axis=-1),
            jnp.take_along_axis(all_dist, order, axis=-1),
            jnp.take_along_axis(all_exp, order, axis=-1))


def _random_pool(r, b, m, ef, n_valid, quant):
    """Sorted pool with an INVALID/inf tail and (optionally) heavy ties."""
    dist = np.sort(np.round(r.random((b, m, ef)) * quant) / quant, axis=-1)
    ids = r.integers(0, 10_000, size=(b, m, ef)).astype(np.int32)
    slot = np.arange(ef)[None, None, :]
    dist = np.where(slot < n_valid, dist, np.inf).astype(np.float32)
    ids = np.where(slot < n_valid, ids, INVALID).astype(np.int32)
    exp = (r.random((b, m, ef)) > 0.5) & (slot < n_valid)
    return jnp.asarray(ids), jnp.asarray(dist), jnp.asarray(exp)


def _random_cands(r, b, m, kx, p_invalid, quant):
    dist = np.round(r.random((b, m, kx)) * quant) / quant
    ids = r.integers(0, 10_000, size=(b, m, kx)).astype(np.int32)
    invalid = r.random((b, m, kx)) < p_invalid
    dist = np.where(invalid, np.inf, dist).astype(np.float32)
    ids = np.where(invalid, INVALID, ids).astype(np.int32)
    return jnp.asarray(ids), jnp.asarray(dist)


@pytest.mark.parametrize("ef,kx", [(8, 8), (16, 4), (16, 64), (32, 128),
                                   (1, 16)])
@pytest.mark.parametrize("quant,p_invalid,n_valid_frac", [
    (4, 0.3, 1.0),      # massive ties, some INVALID candidates
    (1, 0.0, 1.0),      # EVERY distance identical: pure tie-order test
    (1000, 0.9, 0.25),  # mostly-INVALID candidates, mostly-padding pool
    (1000, 1.0, 0.5),   # all candidates INVALID
])
def test_topk_merge_byte_equals_argsort_merge(ef, kx, quant, p_invalid,
                                              n_valid_frac):
    r = np.random.default_rng(ef * 1000 + kx + quant)
    b, m = 5, 2
    n_valid = max(1, int(ef * n_valid_frac))
    pi, pd, pe = _random_pool(r, b, m, ef, n_valid, quant)
    ci, cd = _random_cands(r, b, m, kx, p_invalid, quant)
    got = search._merge_topk(pi, pd, pe, ci, cd)
    exp = argsort_merge(pi, pd, pe, ci, cd)
    for g, e, name in zip(got, exp, ("ids", "dist", "expanded")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e),
                                      err_msg=f"merge {name} diverged")


def test_topk_merge_tie_priority_pool_wins():
    """A candidate at exactly a pool entry's distance must rank after it
    (the stable concat order), and tied candidates keep flat order."""
    pi = jnp.asarray([[[1, 2, 3]]], jnp.int32)
    pd = jnp.asarray([[[0.5, 0.5, jnp.inf]]], jnp.float32)
    pe = jnp.asarray([[[True, False, False]]])
    ci = jnp.asarray([[[7, 8]]], jnp.int32)
    cd = jnp.asarray([[[0.5, 0.5]]], jnp.float32)
    ids, dist, exp = search._merge_topk(pi, pd, pe, ci, cd)
    np.testing.assert_array_equal(np.asarray(ids), [[[1, 2, 7]]])
    np.testing.assert_array_equal(np.asarray(exp), [[[True, False, False]]])
    np.testing.assert_array_equal(np.asarray(dist), [[[0.5, 0.5, 0.5]]])


def test_w1_dense_search_bit_identical_to_argsort_reference(small_dataset):
    """W=1 dense search under the top-k merge returns byte-identical pools
    AND exact counters vs the pre-refactor full-argsort merge — the
    no-regression contract on the paper-exact path."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    new = search.knn_search(adj, data, queries, 10, 30, 0)
    orig = search._merge_topk
    search._merge_topk = argsort_merge
    search.beam_search.clear_cache()
    try:
        ref = search.knn_search(adj, data, queries, 10, 30, 0)
    finally:
        search._merge_topk = orig
        search.beam_search.clear_cache()
    np.testing.assert_array_equal(np.asarray(new.pool_ids),
                                  np.asarray(ref.pool_ids))
    np.testing.assert_array_equal(np.asarray(new.pool_dist),
                                  np.asarray(ref.pool_dist))
    assert int(new.n_fresh) == int(ref.n_fresh)
    assert int(new.n_computed) == int(ref.n_computed)
    assert int(new.hops) == int(ref.hops)


def test_w1_multigraph_eso_bit_identical_to_argsort_reference(small_dataset):
    """Same byte-identity contract on the multi-graph ESO builder path."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    pad = jnp.full((adj.shape[0], 4), INVALID, jnp.int32)
    g2 = jnp.stack([adj, jnp.concatenate([adj[:, :6], pad], axis=1)])
    b = 8
    args = (g2, data, queries[:b], jnp.full((b,), INVALID, jnp.int32),
            jnp.ones((b,), bool), jnp.array([15, 10], jnp.int32),
            jnp.zeros((b, 2), jnp.int32))
    kw = dict(ef_max=15, max_hops=60, share_cache=True)
    new = search.beam_search(*args, **kw)
    orig = search._merge_topk
    search._merge_topk = argsort_merge
    search.beam_search.clear_cache()
    try:
        ref = search.beam_search(*args, **kw)
    finally:
        search._merge_topk = orig
        search.beam_search.clear_cache()
    np.testing.assert_array_equal(np.asarray(new.pool_ids),
                                  np.asarray(ref.pool_ids))
    np.testing.assert_array_equal(np.asarray(new.pool_dist),
                                  np.asarray(ref.pool_dist))
    assert int(new.n_computed) == int(ref.n_computed)
    assert int(new.n_fresh) == int(ref.n_fresh)


@pytest.mark.parametrize("impl", ["dense", "hash"])
@pytest.mark.parametrize("metric", METRICS)
def test_expand_width_recall_parity_10k(metric, impl):
    """Acceptance: W=4 recall@k within tolerance of W=1 on 10k points,
    with the hop count dropping (the latency the width buys)."""
    n, d, b, k, ef = 10_000, 16, 32, 10, 32
    r = np.random.default_rng(11)
    data = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    queries = data[:b] + 0.1 * jnp.asarray(r.normal(size=(b, d)),
                                           jnp.float32)
    adj = random_knng_ids(1, n, 16)
    gt = evallib.ground_truth(data, queries, k, metric=metric)
    r1 = search.knn_search(adj, data, queries, k, ef, 0, metric=metric,
                           visited_impl=impl, expand_width=1)
    r4 = search.knn_search(adj, data, queries, k, ef, 0, metric=metric,
                           visited_impl=impl, expand_width=4)
    rec1 = evallib.recall_at_k(r1.pool_ids[:, :k], gt)
    rec4 = evallib.recall_at_k(r4.pool_ids[:, :k], gt)
    assert rec4 >= rec1 - 0.02, (rec1, rec4)
    assert int(r4.hops) < int(r1.hops)
    # the W-wide schedule may overshoot the sequential #dist, never undershoot
    # by more than the tail effect; pin the deterministic workload
    assert int(r4.n_computed) >= int(r1.n_computed)


def test_expand_width_pools_stay_sorted_and_duplicate_free(small_dataset):
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    for impl in ("dense", "hash"):
        res = search.knn_search(adj, data, queries, 10, 24, 0,
                                visited_impl=impl, expand_width=3)
        dist = np.asarray(res.pool_dist)
        assert np.all(np.diff(dist, axis=-1) >= 0), "pool not sorted"
        for row in np.asarray(res.pool_ids):
            real = [x for x in row.tolist() if x >= 0]
            assert len(real) == len(set(real)), "duplicate ids in pool"


def test_expand_width_clamps_to_ef():
    """W > ef cannot expand more than the pool holds — clamped, not an
    error (the HNSW descent calls with ef_max=1)."""
    r = np.random.default_rng(0)
    data = jnp.asarray(r.normal(size=(300, 8)), jnp.float32)
    adj = random_knng_ids(0, 300, 8)
    a = search.knn_search(adj, data, data[:4], 2, 4, 0, expand_width=64)
    b = search.knn_search(adj, data, data[:4], 2, 4, 0, expand_width=4)
    np.testing.assert_array_equal(np.asarray(a.pool_ids),
                                  np.asarray(b.pool_ids))


def test_expand_width_rejected_below_one(small_dataset):
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    with pytest.raises(ValueError, match="expand_width"):
        search.knn_search(adj, data, queries, 5, 10, 0, expand_width=0)


def test_node_zero_after_invalid_padding_is_not_dropped():
    """In-hop dedup must compare raw ids: clamping INVALID to 0 would alias
    padding with a genuine id-0 candidate arriving later in the W·Mx
    window and silently drop it (regression for the widened window)."""
    r = np.random.default_rng(5)
    n, d = 64, 4
    data = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    # entry 1's adjacency: an INVALID pad lane BEFORE the node-0 lane
    adj = jnp.full((n, 4), INVALID, jnp.int32)
    adj = adj.at[1].set(jnp.array([2, INVALID, 0, 3], jnp.int32))
    adj = adj.at[0].set(jnp.array([1, 2, 3, INVALID], jnp.int32))
    q = data[0][None] * 0.9            # node 0 is the closest neighbor
    res = search.knn_search(adj, data, q, 4, 8, 1)
    ids = set(np.asarray(res.pool_ids[0]).tolist())
    assert 0 in ids, "id-0 candidate was dropped as a padding duplicate"
