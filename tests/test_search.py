"""Beam search: reference equality, ESO cache invariance, counter laws."""
import heapq

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knng, search
from repro.core.graph import INVALID


def kanns_python(adj, data, q, ef, ep):
    """Literal Algorithm 1 (returns sorted [(dist, id)] of pool)."""
    d0 = float(np.sum((data[ep] - q) ** 2))
    pool = [(d0, ep)]
    expanded = set()
    visited = {ep}
    n_dist = 1
    while True:
        pool.sort()
        pool = pool[:ef]
        u = next(((dd, ii) for dd, ii in pool if ii not in expanded), None)
        if u is None:
            break
        expanded.add(u[1])
        for v in adj[u[1]]:
            if v < 0 or v in visited:
                continue
            visited.add(v)
            n_dist += 1
            pool.append((float(np.sum((data[v] - q) ** 2)), v))
    pool.sort()
    return pool[:ef], n_dist


@pytest.mark.parametrize("ef", [4, 10, 25])
def test_beam_search_matches_python(small_dataset, ef):
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    adj_np = np.asarray(adj)
    data_np = np.asarray(data)
    res = search.knn_search(adj, data, queries[:10], min(ef, 5), ef, 0)
    for qi in range(10):
        exp, _ = kanns_python(adj_np, data_np, np.asarray(queries[qi]),
                              ef, 0)
        got_ids = [int(i) for i in np.asarray(res.pool_ids[qi]) if i >= 0]
        exp_ids = [i for _, i in exp][:len(got_ids)]
        assert got_ids == exp_ids[:min(ef, 5)][:len(got_ids)]


def test_eso_cache_does_not_change_results(small_dataset):
    """Pools identical with and without the shared V_delta (ESO is a pure
    caching optimization); computed <= fresh with equality when m == 1."""
    data, queries = small_dataset
    n = data.shape[0]
    adj, _ = knng.build_knng(data, 10)
    pad = jnp.full((n, 4), INVALID, jnp.int32)
    g2 = jnp.stack([adj, jnp.concatenate([adj[:, :6], pad], axis=1)])
    b = 16
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.ones((b,), bool)
    ef = jnp.array([20, 12], jnp.int32)
    ep = jnp.zeros((b, 2), jnp.int32)
    kw = dict(ef_max=20, max_hops=80)
    r1 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=True, **kw)
    r2 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=False, **kw)
    np.testing.assert_array_equal(np.asarray(r1.pool_ids),
                                  np.asarray(r2.pool_ids))
    assert int(r1.n_fresh) == int(r2.n_fresh)
    assert int(r1.n_computed) < int(r1.n_fresh)      # overlap ⇒ cache hits
    assert int(r2.n_computed) == int(r2.n_fresh)


def test_counters_union_bound(small_dataset):
    """#computed with ESO == |union of (q, v) pairs| — never more than the
    per-graph sum, never less than the largest single graph."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    g2 = jnp.stack([adj, adj])               # identical graphs: full overlap
    b = 8
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.ones((b,), bool)
    ef = jnp.array([15, 15], jnp.int32)
    ep = jnp.zeros((b, 2), jnp.int32)
    r = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                           ef_max=15, max_hops=60, share_cache=True)
    # identical graphs: every distance is computed exactly once
    assert int(r.n_computed) * 2 == int(r.n_fresh)


def test_padding_rows_do_no_work(small_dataset):
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    b = 8
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.zeros((b,), bool).at[0].set(True)
    r = search.beam_search(adj[None], data, queries[:b], qids, row,
                           jnp.array([10], jnp.int32),
                           jnp.zeros((b, 1), jnp.int32),
                           ef_max=10, max_hops=50, share_cache=False)
    assert bool(jnp.all(r.pool_ids[1:] == INVALID))
