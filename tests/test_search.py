"""Beam search: reference equality (per metric), ESO cache invariance,
counter laws, and the metric="l2" bit-identity regression guard."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knng, metric as metric_lib, search
from repro.core.graph import INVALID

METRICS = ["l2", "ip", "cosine"]


def _dist_np(q, x, metric):
    """The core/metric.py distance convention, in numpy."""
    met = metric_lib.resolve(metric)
    if met.normalize:
        q = q / max(np.linalg.norm(q), 1e-12)
        x = x / max(np.linalg.norm(x), 1e-12)
    if met.kernel == "ip":
        return float(1.0 - np.dot(q, x))
    return float(np.sum((q - x) ** 2))


def kanns_python(adj, data, q, ef, ep, metric="l2"):
    """Literal Algorithm 1 (returns sorted [(dist, id)] of pool)."""
    d0 = _dist_np(q, data[ep], metric)
    pool = [(d0, ep)]
    expanded = set()
    visited = {ep}
    n_dist = 1
    while True:
        pool.sort()
        pool = pool[:ef]
        u = next(((dd, ii) for dd, ii in pool if ii not in expanded), None)
        if u is None:
            break
        expanded.add(u[1])
        for v in adj[u[1]]:
            if v < 0 or v in visited:
                continue
            visited.add(v)
            n_dist += 1
            pool.append((_dist_np(q, data[v], metric), v))
    pool.sort()
    return pool[:ef], n_dist


@pytest.mark.parametrize("ef", [4, 10, 25])
@pytest.mark.parametrize("metric", METRICS)
def test_beam_search_matches_python(small_dataset, ef, metric):
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12, metric=metric)
    adj_np = np.asarray(adj)
    data_np = np.asarray(data)
    res = search.knn_search(adj, data, queries[:10], min(ef, 5), ef, 0,
                            metric=metric)
    for qi in range(10):
        exp, _ = kanns_python(adj_np, data_np, np.asarray(queries[qi]),
                              ef, 0, metric)
        got_ids = [int(i) for i in np.asarray(res.pool_ids[qi]) if i >= 0]
        exp_ids = [i for _, i in exp][:len(got_ids)]
        assert got_ids == exp_ids[:min(ef, 5)][:len(got_ids)]


def test_knn_search_l2_bit_identical_to_default(small_dataset):
    """metric="l2" must return bit-identical pools AND counters to calling
    knn_search without a metric (the pre-refactor default) — the refactor's
    no-regression contract on the synthetic dataset."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 12)
    a = search.knn_search(adj, data, queries, 10, 30, 0)
    b = search.knn_search(adj, data, queries, 10, 30, 0, metric="l2")
    np.testing.assert_array_equal(np.asarray(a.pool_ids),
                                  np.asarray(b.pool_ids))
    np.testing.assert_array_equal(np.asarray(a.pool_dist),
                                  np.asarray(b.pool_dist))
    assert int(a.n_fresh) == int(b.n_fresh)
    assert int(a.n_computed) == int(b.n_computed)
    assert int(a.hops) == int(b.hops)


@pytest.mark.parametrize("metric", ["ip", "cosine"])
def test_eso_cache_invariance_other_metrics(small_dataset, metric):
    """The ESO cache stays a pure optimization under every metric."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10, metric=metric)
    g2 = jnp.stack([adj, adj])
    b = 8
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.ones((b,), bool)
    ef = jnp.array([12, 12], jnp.int32)
    ep = jnp.zeros((b, 2), jnp.int32)
    kw = dict(ef_max=12, max_hops=60, metric=metric)
    r1 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=True, **kw)
    r2 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=False, **kw)
    np.testing.assert_array_equal(np.asarray(r1.pool_ids),
                                  np.asarray(r2.pool_ids))
    assert int(r1.n_computed) * 2 == int(r1.n_fresh)   # identical graphs


def test_eso_cache_does_not_change_results(small_dataset):
    """Pools identical with and without the shared V_delta (ESO is a pure
    caching optimization); computed <= fresh with equality when m == 1."""
    data, queries = small_dataset
    n = data.shape[0]
    adj, _ = knng.build_knng(data, 10)
    pad = jnp.full((n, 4), INVALID, jnp.int32)
    g2 = jnp.stack([adj, jnp.concatenate([adj[:, :6], pad], axis=1)])
    b = 16
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.ones((b,), bool)
    ef = jnp.array([20, 12], jnp.int32)
    ep = jnp.zeros((b, 2), jnp.int32)
    kw = dict(ef_max=20, max_hops=80)
    r1 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=True, **kw)
    r2 = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                            share_cache=False, **kw)
    np.testing.assert_array_equal(np.asarray(r1.pool_ids),
                                  np.asarray(r2.pool_ids))
    assert int(r1.n_fresh) == int(r2.n_fresh)
    assert int(r1.n_computed) < int(r1.n_fresh)      # overlap ⇒ cache hits
    assert int(r2.n_computed) == int(r2.n_fresh)


def test_counters_union_bound(small_dataset):
    """#computed with ESO == |union of (q, v) pairs| — never more than the
    per-graph sum, never less than the largest single graph."""
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    g2 = jnp.stack([adj, adj])               # identical graphs: full overlap
    b = 8
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.ones((b,), bool)
    ef = jnp.array([15, 15], jnp.int32)
    ep = jnp.zeros((b, 2), jnp.int32)
    r = search.beam_search(g2, data, queries[:b], qids, row, ef, ep,
                           ef_max=15, max_hops=60, share_cache=True)
    # identical graphs: every distance is computed exactly once
    assert int(r.n_computed) * 2 == int(r.n_fresh)


def test_padding_rows_do_no_work(small_dataset):
    data, queries = small_dataset
    adj, _ = knng.build_knng(data, 10)
    b = 8
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.zeros((b,), bool).at[0].set(True)
    r = search.beam_search(adj[None], data, queries[:b], qids, row,
                           jnp.array([10], jnp.int32),
                           jnp.zeros((b, 1), jnp.int32),
                           ef_max=10, max_hops=50, share_cache=False)
    assert bool(jnp.all(r.pool_ids[1:] == INVALID))
