import os

# Force a multi-device CPU topology BEFORE jax initializes (conftest runs
# ahead of test-module imports): the sharded-search suite must cross real
# device boundaries (DESIGN.md §11), and every other test is
# device-count-agnostic.  Respect an explicit operator setting.
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_dataset():
    """Clustered (data, queries) used across core tests."""
    import jax.numpy as jnp
    r = np.random.default_rng(7)
    centers = r.normal(size=(8, 16)) * 3.0
    data = centers[r.integers(0, 8, 800)] + r.normal(size=(800, 16))
    queries = centers[r.integers(0, 8, 40)] + r.normal(size=(40, 16))
    return (jnp.asarray(data, jnp.float32), jnp.asarray(queries, jnp.float32))
