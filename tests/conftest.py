import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_dataset():
    """Clustered (data, queries) used across core tests."""
    import jax.numpy as jnp
    r = np.random.default_rng(7)
    centers = r.normal(size=(8, 16)) * 3.0
    data = centers[r.integers(0, 8, 800)] + r.normal(size=(800, 16))
    queries = centers[r.integers(0, 8, 40)] + r.normal(size=(40, 16))
    return (jnp.asarray(data, jnp.float32), jnp.asarray(queries, jnp.float32))
