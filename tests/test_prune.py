"""RNG pruning: sequential-reference equality + the paper's theorems as
hypothesis property tests (Theorem 1: R-prefix, Theorem 2: M-prefix) and
EPO soundness (mPrune == Prune when alphas ascend).

``hypothesis`` is optional: without it the property tests degrade to a
single deterministic example each (the suite must still collect)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

    def settings(**kw):
        return lambda f: f

    class _Just:
        """Degraded @given: call the test once with each strategy's example."""
        def __init__(self, example):
            self.example = example

    class st:   # noqa: N801 - mirrors the hypothesis namespace
        @staticmethod
        def integers(lo, hi):
            return _Just((lo + hi) // 2)

        @staticmethod
        def floats(lo, hi):
            return _Just((lo + hi) / 2.0)

    def given(*strats):
        def deco(f):
            def wrapper():
                return f(*(s.example for s in strats))
            return wrapper
        return deco

from repro.core import prune


def prune_python(cand_dist, pair_dist, M, alpha):
    """Literal Algorithm 2 (returns accepted index list)."""
    pn = []
    for j in range(len(cand_dist)):
        if len(pn) >= M:
            break
        dominated = False
        for w in pn:
            if alpha * pair_dist[j, w] < cand_dist[j]:
                dominated = True
        if not dominated:
            pn.append(j)
    return pn


def _mk_case(r, n_cand, d=8):
    pts = r.normal(size=(n_cand, d))
    u = r.normal(size=(d,))
    cd = np.sum((pts - u) ** 2, axis=1)
    order = np.argsort(cd)
    pts, cd = pts[order], cd[order]
    pd = np.sum((pts[:, None] - pts[None, :]) ** 2, axis=2)
    return cd.astype(np.float32), pd.astype(np.float32)


@pytest.mark.parametrize("n_cand,M,alpha", [(20, 6, 1.0), (50, 10, 1.2),
                                            (9, 20, 1.5), (32, 4, 1.0)])
def test_rng_prune_matches_python(n_cand, M, alpha):
    r = np.random.default_rng(n_cand * 7 + M)
    cd, pd = _mk_case(r, n_cand)
    exp = prune_python(cd, pd, M, alpha)
    res = prune.rng_prune(
        jnp.arange(n_cand, dtype=jnp.int32)[None],
        jnp.asarray(cd)[None], jnp.asarray(pd)[None],
        jnp.ones((1, n_cand), bool), jnp.int32(M), jnp.float32(alpha),
        m_max=M)
    got = np.flatnonzero(np.asarray(res.accepted[0])).tolist()
    assert got == exp


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 30), st.integers(1, 12), st.integers(0, 10_000),
       st.floats(1.0, 2.0))
def test_theorem2_m_prefix(n_cand, m_small, seed, alpha):
    """PN(M) ⊆ PN(M') for M <= M' (Theorem 2)."""
    r = np.random.default_rng(seed)
    cd, pd = _mk_case(r, n_cand)
    small = set(prune_python(cd, pd, m_small, alpha))
    big = set(prune_python(cd, pd, m_small + r.integers(0, 10), alpha))
    assert small <= big


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 30), st.integers(1, 20), st.integers(0, 10_000),
       st.floats(1.0, 2.0))
def test_theorem1_r_prefix(n_cand, r_small, seed, alpha):
    """PN over candidate prefix R ⊆ PN over prefix R' >= R (Theorem 1)."""
    r = np.random.default_rng(seed)
    cd, pd = _mk_case(r, n_cand)
    M = 6
    r_small = min(r_small, n_cand)
    r_big = min(r_small + int(r.integers(0, 10)), n_cand)
    small = set(prune_python(cd[:r_small], pd[:r_small, :r_small], M, alpha))
    big = set(prune_python(cd[:r_big], pd[:r_big, :r_big], M, alpha))
    assert small <= big


@settings(max_examples=25, deadline=None)
@given(st.integers(5, 25), st.integers(0, 10_000),
       st.floats(1.0, 1.5), st.floats(0.0, 0.5))
def test_epo_soundness(n_cand, seed, alpha_lo, alpha_gap):
    """mPrune with the pair-skip produces EXACTLY plain Prune's result when
    the group ascends in alpha (DESIGN.md §2 item 4)."""
    r = np.random.default_rng(seed)
    d = 8
    pts = r.normal(size=(60, d)).astype(np.float32)
    data = jnp.asarray(pts)
    ids = np.sort(r.choice(60, size=n_cand, replace=False))
    u = r.normal(size=(d,)).astype(np.float32)
    cd = np.sum((pts[ids] - u) ** 2, axis=1)
    order = np.argsort(cd)
    ids, cd = ids[order], cd[order]

    m_lims = jnp.array([5, 7], jnp.int32)
    alphas = jnp.array([alpha_lo, alpha_lo + alpha_gap], jnp.float32)
    cand_ids = jnp.asarray(np.stack([ids, ids]), jnp.int32)[:, None, :]
    cand_dist = jnp.asarray(np.stack([cd, cd]), jnp.float32)[:, None, :]
    valid = jnp.ones_like(cand_ids, dtype=bool)

    with_epo, _, nc_epo = prune.multi_prune(
        data, cand_ids, cand_dist, valid, m_lims, alphas, m_max=8,
        use_epo=True)
    without, _, nc_plain = prune.multi_prune(
        data, cand_ids, cand_dist, valid, m_lims, alphas, m_max=8,
        use_epo=False)
    for a, b in zip(with_epo, without):
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert int(nc_epo) <= int(nc_plain)


def test_epo_saves_checks_on_overlapping_candidates():
    r = np.random.default_rng(3)
    # candidates on shells around u => pairwise distances exceed distances
    # to u => PN fills up => graph-2 re-checks of accepted pairs are skipped
    u = np.zeros(8, np.float32)
    dirs = r.normal(size=(30, 8))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    radii = np.linspace(1.0, 2.0, 30)[:, None]
    pts = (dirs * radii).astype(np.float32)
    data = jnp.asarray(np.concatenate([pts, u[None]]))
    cd = np.sum(pts ** 2, axis=1)
    order = np.argsort(cd)
    ids = np.arange(30)[order]
    cand_ids = jnp.asarray(np.stack([ids, ids]), jnp.int32)[:, None, :]
    cand_dist = jnp.asarray(np.stack([cd[order]] * 2))[:, None, :]
    valid = jnp.ones_like(cand_ids, dtype=bool)
    _, nb, nc = prune.multi_prune(
        data, cand_ids, cand_dist, valid,
        jnp.array([8, 8], jnp.int32), jnp.array([1.0, 1.0], jnp.float32),
        m_max=8, use_epo=True)
    assert int(nc) < int(nb)        # identical candidate lists: big savings
