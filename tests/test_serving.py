"""Serving: engine slot recycling + retrieval attention quality."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import vamana
from repro.models import model as M
from repro.serve import retrieval
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = registry.get_config("granite_3_8b").smoke()
    cfg = dataclasses.replace(cfg, vocab=64, n_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def test_engine_completes_requests(tiny_model):
    params, cfg = tiny_model
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=np.array([1 + i, 5, 9]), max_new=4)
            for i in range(5)]
    done = eng.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_engine_batches_share_slots(tiny_model):
    params, cfg = tiny_model
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.array([2, 3]), max_new=2))
    ticks = 0
    while any(s is not None for s in eng.slots) or eng.queue:
        eng.step()
        ticks += 1
        assert ticks < 50


def test_retrieval_attention_approximates_exact():
    """With concentrated attention, PG top-k recovers the dense result."""
    r = np.random.default_rng(0)
    n, dh, b = 400, 16, 8
    keys = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    values = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    # queries close to specific keys -> attention mass concentrated
    tgt = r.integers(0, n, b)
    q = keys[tgt] * 4.0
    idx = retrieval.build_index(
        keys, values, vamana.VamanaParams(L=32, M=12, alpha=1.2))
    approx, res = retrieval.retrieval_attention(idx, q, top_k=32, ef=48)
    exact = retrieval.exact_attention(keys, values, q)
    cos = jnp.sum(approx * exact, -1) / (
        jnp.linalg.norm(approx, axis=-1) * jnp.linalg.norm(exact, axis=-1))
    assert float(jnp.mean(cos)) > 0.97
    assert int(res.n_computed) < b * n * 0.8   # sub-linear vs exhaustive


def test_retrieval_attention_batched_matches_unbatched():
    """Query blocking (static bucketed shapes + row-mask padding) is a pure
    scheduling change: identical pools, outputs, and #dist counters."""
    r = np.random.default_rng(2)
    n, dh, B = 400, 16, 20
    keys = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    values = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    q = keys[r.integers(0, n, B)] * 4.0
    idx = retrieval.build_index(
        keys, values, vamana.VamanaParams(L=32, M=12, alpha=1.2))
    out, res = retrieval.retrieval_attention(idx, q, top_k=16, ef=32)
    outb, resb = retrieval.retrieval_attention_batched(
        idx, q, top_k=16, ef=32, block_size=8)
    np.testing.assert_array_equal(np.asarray(res.pool_ids),
                                  np.asarray(resb.pool_ids))
    assert bool(jnp.allclose(out, outb, atol=1e-5))
    assert int(res.n_computed) == int(resb.n_computed)
    # ragged tail: B smaller than one block pads up and strips cleanly
    out3, _ = retrieval.retrieval_attention_batched(
        idx, q[:5], top_k=16, ef=32, block_size=64)
    assert bool(jnp.allclose(out3, out[:5], atol=1e-5))


def test_retrieval_attention_dense_hash_agree():
    """Serving's hash default returns the same retrieved set as the dense
    bitmap path on a real built index."""
    r = np.random.default_rng(4)
    keys = jnp.asarray(r.normal(size=(300, 8)), jnp.float32)
    vals = jnp.asarray(r.normal(size=(300, 8)), jnp.float32)
    idx = retrieval.build_index(
        keys, vals, vamana.VamanaParams(L=24, M=8, alpha=1.2))
    q = keys[:6] * 2
    _, rh = retrieval.retrieval_attention(idx, q, top_k=8, ef=16)
    _, rd = retrieval.retrieval_attention(idx, q, top_k=8, ef=16,
                                          visited_impl="dense")
    np.testing.assert_array_equal(np.asarray(rh.pool_ids),
                                  np.asarray(rd.pool_ids))


def test_sharded_index_serves_and_blocks_consistently():
    """build_index(num_shards>1): scatter-gather retrieval returns global
    key ids, approximates exact attention like the unsharded index, and
    the batched path stays a pure scheduling change (DESIGN.md §11)."""
    r = np.random.default_rng(6)
    n, dh, b = 400, 16, 12
    keys = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    vals = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    q = keys[r.integers(0, n, b)] * 4.0
    idx = retrieval.build_index(
        keys, vals, vamana.VamanaParams(L=32, M=12, alpha=1.2),
        num_shards=4)
    assert idx.num_shards == 4 and idx.graph_ids is None
    out, res = retrieval.retrieval_attention(idx, q, top_k=16, ef=32)
    ids = np.asarray(res.pool_ids)
    assert ids.min() >= 0 and ids.max() < n          # global, real ids
    exact = retrieval.exact_attention(keys, vals, q)
    cos = jnp.sum(out * exact, -1) / (
        jnp.linalg.norm(out, axis=-1) * jnp.linalg.norm(exact, axis=-1))
    assert float(jnp.mean(cos)) > 0.97
    outb, resb = retrieval.retrieval_attention_batched(
        idx, q, top_k=16, ef=32, block_size=8)
    np.testing.assert_array_equal(ids, np.asarray(resb.pool_ids))
    assert int(res.n_computed) == int(resb.n_computed)
    assert bool(jnp.allclose(out, outb, atol=1e-5))


def test_retrieval_knobs_num_shards():
    from repro.serve.engine import RetrievalKnobs
    assert RetrievalKnobs().index_kwargs() == {
        "num_shards": 1, "build_impl": "per_batch", "assign": "chunked",
        "quantize": "none"}
    assert RetrievalKnobs(num_shards=4, build_impl="fused").index_kwargs() == {
        "num_shards": 4, "build_impl": "fused", "assign": "chunked",
        "quantize": "none"}
    with pytest.raises(ValueError, match="num_shards"):
        RetrievalKnobs(num_shards=0)
    with pytest.raises(ValueError, match="build_impl"):
        RetrievalKnobs(build_impl="nope")


def test_retrieval_knobs_routing():
    """assign / routed_shards (DESIGN.md §13) thread through the knob
    kwargs and are validated at construction, not at search time."""
    from repro.serve.engine import RetrievalKnobs
    knobs = RetrievalKnobs(num_shards=4, assign="kmeans", routed_shards=2)
    assert knobs.index_kwargs()["assign"] == "kmeans"
    assert knobs.search_kwargs()["routed_shards"] == 2
    assert knobs.batched_kwargs()["routed_shards"] == 2
    assert RetrievalKnobs().search_kwargs()["routed_shards"] is None
    with pytest.raises(ValueError, match="assign"):
        RetrievalKnobs(assign="hashed")
    with pytest.raises(ValueError, match="routed_shards"):
        RetrievalKnobs(routed_shards=2)        # > num_shards=1
    with pytest.raises(ValueError, match="routed_shards"):
        RetrievalKnobs(num_shards=4, routed_shards=0)


def test_retrieval_knobs_quantize():
    """The quantize knob (DESIGN.md §16) validates at construction and
    threads through index_kwargs; search paths read it off the index."""
    from repro.serve.engine import RetrievalKnobs
    assert RetrievalKnobs(quantize="sq8").index_kwargs()["quantize"] == "sq8"
    with pytest.raises(ValueError, match="quantize"):
        RetrievalKnobs(quantize="sq4")


def test_quantized_index_serves():
    """build_index(quantize="sq8") end to end: the index carries the
    quantized corpus, search runs sq8 + fp32 re-rank, and the re-rank
    keeps exact-attention quality at fp32 level."""
    r = np.random.default_rng(12)
    n, dh, b = 300, 16, 8
    keys = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    vals = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    q = keys[r.integers(0, n, b)] + 0.05 * jnp.asarray(
        r.normal(size=(b, dh)), jnp.float32)
    idx = retrieval.build_index(
        keys, vals, vamana.VamanaParams(L=24, M=8, alpha=1.2),
        quantize="sq8")
    assert idx.quantize == "sq8" and idx.quant is not None
    assert idx.provenance["quantize"] == "sq8"
    out, res = retrieval.retrieval_attention(idx, q, top_k=8, ef=24)
    ids = np.asarray(res.pool_ids)
    assert ids.min() >= 0 and ids.max() < n
    # attention quality parity with the fp32 path on the SAME index (an
    # explicit quantize="none" override forces it): the re-rank restores
    # fp32 distances over the final pool, so outputs track closely
    out32, res32 = retrieval.retrieval_attention(idx, q, top_k=8, ef=24,
                                                 quantize="none")
    exact = retrieval.exact_attention(keys, vals, q)

    def _cos(a):
        return float(jnp.mean(jnp.sum(a * exact, -1) / (
            jnp.linalg.norm(a, axis=-1)
            * jnp.linalg.norm(exact, axis=-1))))

    assert _cos(out) >= _cos(out32) - 0.02
    # the re-rank is counted work on top of the quantized beam
    assert int(res32.n_computed) < int(res.n_computed)


def test_routed_sharded_index_serves():
    """kmeans-built sharded index + routed search: global ids, exact-
    attention quality preserved, and the routed path computes strictly
    fewer distances than scatter-gather (DESIGN.md §13)."""
    r = np.random.default_rng(8)
    n, dh, b = 400, 16, 12
    keys = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    vals = jnp.asarray(r.normal(size=(n, dh)), jnp.float32)
    q = keys[r.integers(0, n, b)] * 4.0
    idx = retrieval.build_index(
        keys, vals, vamana.VamanaParams(L=32, M=12, alpha=1.2),
        num_shards=4, assign="kmeans")
    assert idx.shards.centroids is not None
    out_full, res_full = retrieval.retrieval_attention(
        idx, q, top_k=16, ef=32)
    out, res = retrieval.retrieval_attention(
        idx, q, top_k=16, ef=32, routed_shards=2)
    ids = np.asarray(res.pool_ids)
    assert ids.min() >= 0 and ids.max() < n
    assert int(res.n_computed) < int(res_full.n_computed)
    exact = retrieval.exact_attention(keys, vals, q)
    cos = jnp.sum(out * exact, -1) / (
        jnp.linalg.norm(out, axis=-1) * jnp.linalg.norm(exact, axis=-1))
    assert float(jnp.mean(cos)) > 0.97
    # blocked path stays a pure scheduling change under routing
    outb, resb = retrieval.retrieval_attention_batched(
        idx, q, top_k=16, ef=32, block_size=8, routed_shards=2)
    np.testing.assert_array_equal(ids, np.asarray(resb.pool_ids))
    assert bool(jnp.allclose(out, outb, atol=1e-5))
    # routing on an unsharded index is a user error, caught loudly
    idx1 = retrieval.build_index(
        keys, vals, vamana.VamanaParams(L=32, M=12, alpha=1.2))
    with pytest.raises(ValueError, match="unsharded"):
        retrieval.retrieval_attention(idx1, q, top_k=8, ef=16,
                                      routed_shards=2)


def test_retrieval_index_tunable_by_fastpgt():
    """The serving index is built from the same VamanaParams the tuner
    recommends — integration point of the paper technique."""
    from repro.core.tuner import params as pspace
    sp = pspace.space("vamana", scale=0.1)
    cfg = sp.decode(np.array([0.5, 0.5, 0.5]))
    bp = pspace.to_build_params("vamana", cfg)
    r = np.random.default_rng(1)
    keys = jnp.asarray(r.normal(size=(200, 8)), jnp.float32)
    vals = jnp.asarray(r.normal(size=(200, 8)), jnp.float32)
    idx = retrieval.build_index(keys, vals, bp)
    out, _ = retrieval.retrieval_attention(idx, keys[:4] * 2, top_k=8,
                                           ef=16)
    assert out.shape == (4, 8)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_submit_rejects_oversized_prompt(tiny_model):
    """Prompts longer than max_seq-1 must fail at submit, not corrupt the
    KV cache during _admit's per-token prefill (regression: the overflow
    used to wrap pos past the cache pages and overwrite live slots)."""
    params, cfg = tiny_model
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=16)
    with pytest.raises(ValueError, match="prefill capacity"):
        eng.submit(Request(rid=0, prompt=np.arange(16, dtype=np.int32) % 8))
    # exactly at the limit (max_seq-1 tokens) is admissible and completes
    ok = Request(rid=1, prompt=(np.arange(15, dtype=np.int32) % 8) + 1,
                 max_new=1)
    eng.run([ok])
    assert ok.done and len(ok.out) == 1


def test_knobs_deadline_validation():
    from repro.serve.engine import RetrievalKnobs
    with pytest.raises(ValueError, match="deadline_ms"):
        RetrievalKnobs(deadline_ms=0.0)
    with pytest.raises(ValueError, match="deadline_ms"):
        RetrievalKnobs(deadline_ms=-5.0)
    k = RetrievalKnobs(deadline_ms=25.0)
    # consumed by the resilience layer only — never forwarded to search
    assert "deadline_ms" not in k.search_kwargs()
    assert "deadline_ms" not in k.batched_kwargs()
    assert "deadline_ms" not in k.index_kwargs()


def test_engine_retrieval_attachment(tiny_model):
    """attach_retrieval -> retrieve -> swap_retrieval_index round trip:
    the engine serves search through the resilience layer and hot-swaps
    a restored snapshot without touching decode state."""
    from repro.serve import resilience
    from repro.serve.engine import RetrievalKnobs
    params, cfg = tiny_model
    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=32)
    q = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                    jnp.float32)
    with pytest.raises(ValueError, match="attach_retrieval"):
        eng.retrieve(q)
    with pytest.raises(ValueError, match="attach_retrieval"):
        eng.swap_retrieval_index(None)
    r = np.random.default_rng(3)
    keys = jnp.asarray(r.normal(size=(128, 8)), jnp.float32)
    idx = retrieval.build_index(
        keys, keys, vamana.VamanaParams(L=16, M=6, alpha=1.2))
    rs = eng.attach_retrieval(idx, RetrievalKnobs(top_k=8, ef=16))
    assert isinstance(rs, resilience.ResilientSearcher)
    out, res = eng.retrieve(q)
    assert out.shape == (4, 8) and res.pool_ids.shape == (4, 8)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        resilience.save_index(idx, d)
        eng.swap_retrieval_index(resilience.load_index(d))
    out2, res2 = eng.retrieve(q)
    np.testing.assert_array_equal(np.asarray(res.pool_ids),
                                  np.asarray(res2.pool_ids))
    assert bool(jnp.allclose(out, out2))
