"""Fixed-size open-addressing hash sets for O(ef)-memory search state.

``beam_search`` historically tracked per-(query, graph) visit state in a
dense ``bool[b, m, n]`` bitmap and the shared V_delta has-bit in
``bool[b, n]`` — per-query memory linear in the corpus, which caps serving
at ~10^5 keys.  GPU-era proximity-graph systems (CAGRA-style traversal)
replace the bitmap with a small per-query hash table; this module is that
structure for the jnp/Pallas lockstep search: int32-keyed open addressing,
power-of-two slot counts, linear probing with a fixed probe budget, every
operation expressed as gathers/scatters so it stays jit-able inside a
``lax.while_loop``.

Memory model, sizing, and the collision/counter contract are written down
in DESIGN.md §9.  The short version:

* Lookups have **no false positives**: a slot matches only when it holds
  the exact key, so search never wrongly skips a node.
* A full table (or an exhausted probe budget) degrades to **false
  negatives**: the insert is dropped, the node may be revisited later, and
  ``#dist`` counters over-count relative to dense mode.  ``auto_slots``
  sizes tables to the worst-case insert count (load factor <= 1/2), which
  makes drops rare — but a search approaching that worst case can still
  grow a probe cluster past ``PROBES``, so counter equality with dense
  mode is an expectation, not a guarantee (DESIGN.md §9.3).
* Keys must be non-negative and **distinct within a row** per call
  (callers dedup first); duplicate keys would both report ``inserted``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = -1          # empty-slot sentinel; valid keys are vector ids >= 0
PROBES = 16         # linear-probe budget per lookup/insert
SLOTS_CAP = 1 << 17         # per-(query, graph) visited-table cap
CACHE_SLOTS_CAP = 1 << 18   # per-query V_delta-table cap


def next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def auto_slots(max_hops: int, max_degree: int, *,
               searches: int = 1, cap: int = SLOTS_CAP) -> int:
    """Power-of-two table size covering the worst-case insert count.

    A search expands at most one pool entry per (query, graph) per hop, so
    one search inserts at most ``1 + max_hops * max_degree`` distinct ids
    per (query, graph) — entry point + per-hop adjacency rows (``ef``
    drives this only through the hop bound: ``default_max_hops`` is
    ~3·ef).  Sizing to twice that keeps the load factor <= 1/2, under
    which linear probing terminates well inside ``PROBES`` steps;
    ``searches`` scales the bound for tables shared by several searches —
    m graphs for the V_delta union, times the layer count when a cache is
    carried across an HNSW descent.  The cap bounds memory for very large
    ef/hops; past it — or if a worst-case search grows a probe cluster
    beyond ``PROBES`` — overflow semantics apply (DESIGN.md §9).
    """
    worst = 1 + max_hops * max_degree
    return max(64, min(next_pow2(2 * searches * worst), cap))


def make_tables(shape_prefix: tuple[int, ...], slots: int) -> jax.Array:
    """Empty tables int32[*shape_prefix, slots], all slots EMPTY."""
    if slots & (slots - 1):
        raise ValueError(f"slots must be a power of two, got {slots}")
    return jnp.full(shape_prefix + (slots,), EMPTY, jnp.int32)


def _mix32(x: jax.Array) -> jax.Array:
    """lowbias32 finalizer: avalanche int32 ids into uniform hash bits."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def home_slot(keys: jax.Array, slots: int) -> jax.Array:
    """int32 home slot per key (keys hashed, masked to the table size)."""
    return (_mix32(keys) & jnp.uint32(slots - 1)).astype(jnp.int32)


def lookup_insert(table: jax.Array, keys: jax.Array, active: jax.Array, *,
                  probes: int = PROBES
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Combined membership test + insert, vectorized over leading dims.

    Args:
      table:  int32[..., S] open-addressing tables (S a power of two).
      keys:   int32[..., K] candidate keys, >= 0 wherever ``active``.
      active: bool[..., K]  lanes to process (others untouched).

    Returns ``(table, found, inserted)``: ``found`` marks keys already
    present *before* this call, ``inserted`` marks keys newly stored.
    Active keys that are neither (probe budget exhausted on a full
    cluster) were dropped — the caller treats them as unvisited, which is
    the revisit-tolerant degradation documented in DESIGN.md §9.

    Concurrent inserts within a row race for slots; losers are detected by
    re-reading the slot after the scatter and continue probing, so the
    linear-probing invariant (a stored key sits within ``probes`` steps of
    its home slot) holds for every stored key.
    """
    S = table.shape[-1]
    K = keys.shape[-1]
    tab = table.reshape(-1, S)
    kk = keys.reshape(-1, K)
    rows = jnp.arange(tab.shape[0])[:, None]
    h = home_slot(kk, S)
    found = jnp.zeros(kk.shape, bool)
    inserted = jnp.zeros(kk.shape, bool)
    pending = active.reshape(-1, K)
    for p in range(probes):
        slot = (h + p) & (S - 1)
        cur = jnp.take_along_axis(tab, slot, axis=-1)
        hit = pending & (cur == kk)
        found = found | hit
        pending = pending & ~hit
        attempt = pending & (cur == EMPTY)
        tgt = jnp.where(attempt, slot, S)                  # S = dropped
        tab = tab.at[rows, tgt].set(jnp.where(attempt, kk, EMPTY),
                                    mode="drop")
        won = attempt & (jnp.take_along_axis(tab, slot, axis=-1) == kk)
        inserted = inserted | won
        pending = pending & ~won
    return (tab.reshape(table.shape), found.reshape(active.shape),
            inserted.reshape(active.shape))
