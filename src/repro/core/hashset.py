"""Fixed-size open-addressing hash sets for O(ef)-memory search state.

``beam_search`` historically tracked per-(query, graph) visit state in a
dense ``bool[b, m, n]`` bitmap and the shared V_delta has-bit in
``bool[b, n]`` — per-query memory linear in the corpus, which caps serving
at ~10^5 keys.  GPU-era proximity-graph systems (CAGRA-style traversal)
replace the bitmap with a small per-query hash table; this module is that
structure for the jnp/Pallas lockstep search: int32-keyed open addressing,
power-of-two slot counts, linear probing with a fixed probe budget — the
whole probe window examined in one gather, inserts made race-free in
proposal space and landed in one scatter (DESIGN.md §9) — every operation
expressed as gathers/scatters so it stays jit-able inside a
``lax.while_loop``.

Memory model, sizing, and the collision/counter contract are written down
in DESIGN.md §9.  The short version:

* Lookups have **no false positives**: a slot matches only when it holds
  the exact key, so search never wrongly skips a node.
* A full table (or an exhausted probe budget) degrades to **false
  negatives**: the insert is dropped, the node may be revisited later, and
  ``#dist`` counters over-count relative to dense mode.  ``auto_slots``
  sizes tables to the worst-case insert count (load factor <= 1/2), which
  makes drops rare — but a search approaching that worst case can still
  grow a probe cluster past ``PROBES``, so counter equality with dense
  mode is an expectation, not a guarantee (DESIGN.md §9.3).
* Keys must be non-negative and **distinct within a row** per call
  (callers dedup first); duplicate keys would both report ``inserted``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = -1          # empty-slot sentinel; valid keys are vector ids >= 0
PROBES = 16         # linear-probe budget per lookup/insert
CONFLICT_ROUNDS = 2  # proposal-space conflict-resolution iterations
SLOTS_CAP = 1 << 17         # per-(query, graph) visited-table cap
CACHE_SLOTS_CAP = 1 << 18   # per-query V_delta-table cap


def next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def auto_slots(max_hops: int, max_degree: int, *,
               searches: int = 1, cap: int = SLOTS_CAP) -> int:
    """Power-of-two table size covering the worst-case insert count.

    A search expands ``expand_width`` pool entries per (query, graph) per
    hop, so one search inserts at most ``1 + max_hops * max_degree``
    distinct ids per (query, graph) with ``max_degree`` the per-hop
    candidate width W·Mx — entry point + per-hop adjacency rows (``ef``
    drives this only through the hop bound: ``default_max_hops`` is
    ~3·ef/W, so tables grow sublinearly in W).  Sizing to twice that
    keeps the load factor <= 1/2, under which linear probing terminates
    well inside ``PROBES`` steps;
    ``searches`` scales the bound for tables shared by several searches —
    m graphs for the V_delta union, times the layer count when a cache is
    carried across an HNSW descent.  The cap bounds memory for very large
    ef/hops; past it — or if a worst-case search grows a probe cluster
    beyond ``PROBES`` — overflow semantics apply (DESIGN.md §9).
    """
    worst = 1 + max_hops * max_degree
    return max(64, min(next_pow2(2 * searches * worst), cap))


def make_tables(shape_prefix: tuple[int, ...], slots: int) -> jax.Array:
    """Empty tables int32[*shape_prefix, slots], all slots EMPTY."""
    if slots & (slots - 1):
        raise ValueError(f"slots must be a power of two, got {slots}")
    return jnp.full(shape_prefix + (slots,), EMPTY, jnp.int32)


def _mix32(x: jax.Array) -> jax.Array:
    """lowbias32 finalizer: avalanche int32 ids into uniform hash bits."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def home_slot(keys: jax.Array, slots: int) -> jax.Array:
    """int32 home slot per key (keys hashed, masked to the table size)."""
    return (_mix32(keys) & jnp.uint32(slots - 1)).astype(jnp.int32)


RUN_RANK_TRI_MAX = 128   # K at/below which the O(K²) compare path wins


def _run_rank(vals: jax.Array) -> jax.Array:
    """int32[..., K]: #earlier (flat order) positions holding an equal value.

    Equal values rank by flat position — the deterministic priority both
    proposal steps below rely on.  Two materializations of the same
    semantics: a triangular pairwise compare (O(K²) elementwise, no sort —
    per-row sorts have large fixed costs on CPU and poor minor-axis layouts
    on TPU) for the hop-sized rows the search hot path sends, and a
    stable-sort path past ``RUN_RANK_TRI_MAX`` where the quadratic
    broadcast would dominate memory."""
    K = vals.shape[-1]
    if K <= RUN_RANK_TRI_MAX:
        tri = jnp.tril(jnp.ones((K, K), bool), k=-1)
        same = (vals[..., :, None] == vals[..., None, :]) & tri
        return jnp.sum(same, axis=-1).astype(jnp.int32)
    idx = jnp.arange(K)
    order = jnp.argsort(vals, axis=-1)
    sv = jnp.take_along_axis(vals, order, axis=-1)
    run_start = jnp.concatenate(
        [jnp.ones_like(sv[..., :1], bool), sv[..., 1:] != sv[..., :-1]],
        axis=-1)
    start_idx = jax.lax.cummax(jnp.where(run_start, idx, 0), axis=vals.ndim - 1)
    rank_sorted = (idx - start_idx).astype(jnp.int32)
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(rank_sorted, inv, axis=-1)


def lookup_insert(table: jax.Array, keys: jax.Array, active: jax.Array, *,
                  probes: int = PROBES
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Combined membership test + insert, vectorized over leading dims.

    Args:
      table:  int32[..., S] open-addressing tables (S a power of two).
      keys:   int32[..., K] candidate keys, >= 0 and **distinct within a
              row** wherever ``active`` (callers dedup first — the module
              contract above).  Equal active keys would each claim a slot
              here (the same-home ranking treats them as contenders, not
              duplicates) and all report ``inserted``, multiplying table
              load beyond what ``auto_slots`` sizes for.
      active: bool[..., K]  lanes to process (others untouched).
      probes: linear-probe budget per key (slots examined).

    Returns ``(table, found, inserted)``: ``found`` marks keys already
    present *before* this call, ``inserted`` marks keys newly stored.
    Active keys that are neither (probe budget exhausted on a full
    cluster) were dropped — the caller treats them as unvisited, which is
    the revisit-tolerant degradation documented in DESIGN.md §9.

    The whole probe window is processed at once — ONE (K, probes) gather
    for membership plus ONE scatter for the inserts — instead of the
    ``probes`` sequential gather+scatter rounds of a per-slot loop.  On
    every backend the scatter is the expensive step (it serializes on CPU
    and copies the table when not aliased), and this runs inside every
    ``lax.while_loop`` hop of the serving hot path, so the round count is
    the hash-mode QPS driver (DESIGN.md §9).  A stored key still sits
    within ``probes`` slots of its home, and lookups scan the full budget,
    so membership semantics are unchanged.

    Insert targets are made race-free *before* the scatter, in proposal
    space: pending keys sharing a home slot are ranked in flat order and
    key r proposes its window's r-th empty slot, so same-home contenders —
    the adversarial collision case — get distinct targets within a round;
    keys from different homes whose windows overlap can still propose the
    same slot, which ``CONFLICT_ROUNDS`` bump-and-repropose iterations
    resolve (a cross-home bump can in turn re-collide a key with a
    same-home sibling in a later round).  Whatever is still conflicted
    after the last round is dropped even if its window holds empty slots —
    an insert-drop the overflow contract already tolerates, slightly more
    likely under adversarial mixed-home collisions than the sequential
    re-probe it replaced.  The surviving proposals are distinct per table,
    so the final scatter needs no read-back verification.
    """
    S = table.shape[-1]
    K = keys.shape[-1]
    P = min(probes, S)
    tab = table.reshape(-1, S)
    kk = keys.reshape(-1, K)
    rows = jnp.arange(tab.shape[0])[:, None]
    h = home_slot(kk, S)
    slots = (h[..., None] + jnp.arange(P)) & (S - 1)             # (R, K, P)
    cur = tab[rows[..., None], slots]
    found = active.reshape(-1, K) & jnp.any(cur == kk[..., None], axis=-1)
    pending = active.reshape(-1, K) & ~found

    # rank among pending keys with the same home slot: key r proposes the
    # r-th empty slot of its window
    rank = _run_rank(jnp.where(pending, h, S + jnp.arange(K)))   # (R, K)
    empty = cur == EMPTY
    nth = jnp.cumsum(empty, axis=-1) - 1                         # empty index
    for _ in range(max(1, CONFLICT_ROUNDS)):    # >=1: the loop defines the
        # attempt/slot/bump the insert decision below reads
        target = empty & (nth == rank[..., None])
        attempt = pending & jnp.any(target, axis=-1)
        pos = jnp.argmax(target, axis=-1)
        slot = jnp.take_along_axis(slots, pos[..., None], axis=-1)[..., 0]
        p_slot = jnp.where(attempt, slot, -1 - jnp.arange(K))    # distinct pads
        bump = _run_rank(p_slot)                                 # (R, K)
        rank = rank + bump
    conflicted = bump > 0        # last round's losers: drop, don't race
    inserted = attempt & ~conflicted
    tgt = jnp.where(inserted, slot, S)                           # S = dropped
    tab = tab.at[rows, tgt].set(jnp.where(inserted, kk, EMPTY), mode="drop")
    return (tab.reshape(table.shape), found.reshape(active.shape),
            inserted.reshape(active.shape))
