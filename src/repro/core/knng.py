"""Exact k-nearest-neighbor graph construction via the blocked L2 kernel.

Used for (a) NSG's Initialization phase (the paper uses KGraph/nn-descent;
at the scale this container runs, exact blocked brute force is both faster
and strictly higher quality — documented deviation in DESIGN.md §8) and
(b) ground-truth generation for Recall@k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib
from repro.kernels import ops


def exact_knn(
    data: jax.Array,
    queries: jax.Array,
    k: int,
    *,
    block: int = 1024,
    exclude_self: bool = False,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Exact k-NN of ``queries`` against ``data`` under ``metric``.

    Returns (ids int32[nq, k], dist float32[nq, k]) ascending by distance.
    ``exclude_self`` masks the self-identity match when queries are the
    dataset itself (KNNG construction).
    """
    met = metric_lib.resolve(metric)
    data = met.prepare(data)          # once, not per query block
    queries = met.prepare(queries)
    n = data.shape[0]
    nq = queries.shape[0]
    kk = min(k + (1 if exclude_self else 0), n)

    def one_block(qb, qoff):
        d2 = ops.pairwise_distance(qb, data, met.kernel)   # (b, n)
        if exclude_self:
            rows = qoff + jnp.arange(qb.shape[0])
            cols = jnp.arange(n)
            d2 = jnp.where(cols[None, :] == rows[:, None], jnp.inf, d2)
        neg, idx = jax.lax.top_k(-d2, kk)
        return idx.astype(jnp.int32), -neg

    ids, dist = [], []
    for off in range(0, nq, block):
        qb = queries[off:off + block]
        i, d = one_block(qb, off)
        ids.append(i)
        dist.append(d)
    ids = jnp.concatenate(ids)[:, :k]
    dist = jnp.concatenate(dist)[:, :k]
    return ids, dist


def build_knng(data: jax.Array, k: int, *, block: int = 1024,
               metric: str = "l2") -> tuple[jax.Array, jax.Array]:
    """Exact KNNG over ``data`` (self-match excluded)."""
    return exact_knn(data, data, k, block=block, exclude_self=True,
                     metric=metric)


def knng_dist_count(n: int, nq: int | None = None) -> int:
    """Logical #dist of brute-force KNN (paper accounting: n*nq pairs)."""
    return n * (nq if nq is not None else n)
