"""RNG pruning — Algorithm 2 (Prune) and Algorithm 4 (mPrune / EPO).

The dominance recurrence is order-dependent (candidates processed ascending
by distance; accepted members prune later ones), so the inner loop is a
``lax.fori_loop`` carrying an accepted mask — bit-identical to the paper's
sequential C++ given the same candidate lists (property-tested against
Theorems 1 & 2).

EPO: when pruning graph i's candidate list after graph i-1's, any pair
(v, w) with both endpoints in the *previous accepted set* C'_{i-1}(u) was
already verified non-dominating and is skipped (Alg. 4 lines 5-6).  The skip
is sound when alpha_i >= alpha_{i-1} (dominance needs alpha*d(v,w) < d(u,v);
survival under a smaller alpha implies survival under a larger one), so
builders sort each group ascending by alpha — noted in DESIGN.md.

Counters (paper metrics):
  n_checks_base — dominance checks a standalone Alg. 2 run would perform
                  (each costs one distance computation delta(v, w)).
  n_checks      — checks actually performed after the EPO skip.

The full pairwise candidate-distance matrix is evaluated as one batched MXU
contraction (TPU-native); the counters track the paper's *logical* #dist.
The union-dedup variant (one matrix shared across the m graphs) is a §Perf
hillclimb documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib
from repro.core.graph import INVALID


class PruneResult(NamedTuple):
    ids: jax.Array          # int32[b, M_max] accepted neighbors, sorted by dist
    dist: jax.Array         # float32[b, M_max]
    accepted: jax.Array     # bool[b, L] acceptance mask over input candidates
    n_checks_base: jax.Array
    n_checks: jax.Array


def pairwise_candidate_dist(data: jax.Array, cand_ids: jax.Array,
                            metric: str = "l2") -> jax.Array:
    """float32[b, L, L] metric distances among each row's candidates.

    The alpha-rule's directional occlusion check compares these against the
    candidate-to-u distances, so both must be in the same metric's units
    (core/metric.py convention).
    """
    met = metric_lib.resolve(metric)
    c = met.prepare(data[jnp.maximum(cand_ids, 0)].astype(jnp.float32))
    cross = jnp.einsum("bld,bkd->blk", c, c)                    # (b, L, L)
    if met.kernel == "ip":
        # Clamp at 0: raw-ip pair distances can be negative, which would
        # invert the alpha rule (larger alpha dominating MORE) and break
        # the monotonicity EPO's pair-skip soundness needs (DESIGN.md §4).
        # Cosine pairs lie in [0, 2] already, so this only bites raw ip.
        return jnp.maximum(1.0 - cross, 0.0)
    n2 = jnp.sum(c * c, axis=-1)                                # (b, L)
    pd = n2[:, :, None] + n2[:, None, :] - 2.0 * cross
    return jnp.maximum(pd, 0.0)


@functools.partial(jax.jit, static_argnames=("m_max",))
def rng_prune(
    cand_ids: jax.Array,    # int32[b, L] ascending by distance to u
    cand_dist: jax.Array,   # float32[b, L]
    pair_dist: jax.Array,   # float32[b, L, L]
    valid: jax.Array,       # bool[b, L]
    m_limit: jax.Array,     # int32[] or [b] out-degree limit M
    alpha: jax.Array,       # float32[] pruning parameter
    skip_member: jax.Array | None = None,   # bool[b, L]: id in C'_{i-1}(u)
    *,
    m_max: int,
) -> PruneResult:
    """Alg. 2 when skip_member is None, Alg. 4 (mPrune) otherwise."""
    b, L = cand_ids.shape
    m_limit = jnp.broadcast_to(jnp.asarray(m_limit, jnp.int32), (b,))
    if skip_member is None:
        skip_member = jnp.zeros((b, L), bool)

    def body(j, st):
        accepted, count, nb, nc = st
        dj = cand_dist[:, j]                                    # (b,)
        processed = valid[:, j] & (count < m_limit)             # (b,)
        pd_j = pair_dist[:, j, :]                               # (b, L)
        check = accepted & processed[:, None]                   # PN members
        skip = skip_member & skip_member[:, j][:, None]         # EPO pair skip
        do_check = check & ~skip
        dominated = jnp.any(do_check & (alpha * pd_j < dj[:, None]), axis=-1)
        nb += jnp.sum(check).astype(jnp.int32)
        nc += jnp.sum(do_check).astype(jnp.int32)
        acc_j = processed & ~dominated
        accepted = accepted.at[:, j].set(acc_j)
        count = count + acc_j.astype(jnp.int32)
        return accepted, count, nb, nc

    init = (jnp.zeros((b, L), bool), jnp.zeros((b,), jnp.int32),
            jnp.int32(0), jnp.int32(0))
    accepted, _, nb, nc = jax.lax.fori_loop(0, L, body, init)

    # Compact accepted candidates (order-preserving) into M_max slots.
    key = jnp.where(accepted, jnp.arange(L)[None, :], L)
    order = jnp.argsort(key, axis=-1)[:, :m_max]
    sel = jnp.take_along_axis(accepted, order, axis=-1)
    ids = jnp.where(sel, jnp.take_along_axis(cand_ids, order, axis=-1),
                    INVALID)
    dist = jnp.where(sel, jnp.take_along_axis(cand_dist, order, axis=-1),
                     jnp.inf)
    return PruneResult(ids, dist, accepted, nb, nc)


def member_mask(cand_ids: jax.Array, prev_ids: jax.Array) -> jax.Array:
    """bool[b, L]: cand_ids[b, j] appears in prev_ids[b, :] (and is valid)."""
    eq = cand_ids[:, :, None] == prev_ids[:, None, :]
    return jnp.any(eq & (prev_ids != INVALID)[:, None, :], axis=-1) & (
        cand_ids != INVALID)


def multi_prune(
    data: jax.Array,
    cand_ids: jax.Array,     # int32[m, b, L] per-graph candidates (sorted)
    cand_dist: jax.Array,    # float32[m, b, L]
    valid: jax.Array,        # bool[m, b, L]
    m_limits: jax.Array,     # int32[m]
    alphas: jax.Array,       # float32[m]  (callers sort groups by alpha asc)
    *,
    m_max: int,
    use_epo: bool = True,
    metric: str = "l2",
) -> tuple[list[PruneResult], jax.Array, jax.Array]:
    """Sequentially prune the m candidate sets with EPO chaining (Alg. 4).

    Returns (per-graph PruneResults, n_checks_base total, n_checks total).
    """
    m = cand_ids.shape[0]
    results: list[PruneResult] = []
    prev_acc_ids = None
    nb_tot = jnp.int32(0)
    nc_tot = jnp.int32(0)
    for i in range(m):
        pd = pairwise_candidate_dist(data, cand_ids[i], metric)
        skip = None
        if use_epo and prev_acc_ids is not None:
            skip = member_mask(cand_ids[i], prev_acc_ids)
        res = rng_prune(cand_ids[i], cand_dist[i], pd, valid[i],
                        m_limits[i], alphas[i], skip, m_max=m_max)
        results.append(res)
        nb_tot += res.n_checks_base
        nc_tot += res.n_checks
        prev_acc_ids = res.ids
    return results, nb_tot, nc_tot
