"""Batched edge commits: forward rows + reverse edges with overflow re-prune.

Implements lines 16-19 of Alg. 5 (HNSW) and 10-12 of Alg. 6 (Vamana): after a
node batch's neighbor lists are pruned, every accepted edge (u -> v) yields a
reverse candidate (v -> u); nodes whose degree would exceed M re-prune their
candidate list with Alg. 2, others simply append.

TPU adaptation: reverse edges are grouped by destination with a sort +
run-rank (no atomics), capped at ``k_in`` incoming per destination per batch
(static shape; the drop count is returned and is ~0 at sane batch sizes), and
the overflow re-prune runs as one batched ``rng_prune`` over all affected
destinations.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import INVALID
from repro.core.prune import pairwise_candidate_dist, rng_prune


class ReverseResult(NamedTuple):
    adj_ids: jax.Array
    adj_dist: jax.Array
    n_checks: jax.Array   # prune dominance checks (distance computations)
    n_dropped: jax.Array  # reverse edges dropped by the k_in cap


def scatter_rows(adj_ids, adj_dist, rows, new_ids, new_dist, row_mask):
    """Overwrite adjacency rows for the inserted batch (forward commit)."""
    n = adj_ids.shape[0]
    safe = jnp.where(row_mask, rows, n)
    adj_ids = adj_ids.at[safe].set(new_ids, mode="drop")
    adj_dist = adj_dist.at[safe].set(new_dist, mode="drop")
    return adj_ids, adj_dist


def _group_ranks(sorted_dst: jax.Array) -> jax.Array:
    """Rank of each element within its equal-valued run (sorted input)."""
    e = sorted_dst.shape[0]
    idx = jnp.arange(e)
    is_start = jnp.concatenate(
        [jnp.array([True]), sorted_dst[1:] != sorted_dst[:-1]])
    start_idx = jax.lax.cummax(jnp.where(is_start, idx, 0))
    return idx - start_idx


@functools.partial(jax.jit, static_argnames=("k_in", "m_max", "metric"))
def add_reverse_edges(
    data: jax.Array,
    adj_ids: jax.Array,      # int32[n, M_max]
    adj_dist: jax.Array,     # float32[n, M_max]
    src: jax.Array,          # int32[b] inserted nodes
    fwd_ids: jax.Array,      # int32[b, M_max] their accepted neighbors
    fwd_dist: jax.Array,     # float32[b, M_max]
    row_mask: jax.Array,     # bool[b]
    m_limit: jax.Array,      # int32[] out-degree limit
    alpha: jax.Array,        # float32[]
    *,
    k_in: int,
    m_max: int,
    metric: str = "l2",
) -> ReverseResult:
    n = adj_ids.shape[0]
    b, mx = fwd_ids.shape

    # ---- 1. flatten + group reverse edges by destination -------------------
    valid = (fwd_ids != INVALID) & row_mask[:, None]
    dst = jnp.where(valid, fwd_ids, n).reshape(-1)              # (E,)
    rsrc = jnp.broadcast_to(src[:, None], (b, mx)).reshape(-1)
    rdist = jnp.where(valid, fwd_dist, jnp.inf).reshape(-1)
    order = jnp.argsort(dst)
    dst_s, src_s, dist_s = dst[order], rsrc[order], rdist[order]
    rank = _group_ranks(dst_s)
    keep = (dst_s < n) & (rank < k_in)
    n_dropped = jnp.sum((dst_s < n) & (rank >= k_in)).astype(jnp.int32)

    inc_ids = jnp.full((n, k_in), INVALID, jnp.int32)
    inc_dist = jnp.full((n, k_in), jnp.inf, jnp.float32)
    di = jnp.where(keep, dst_s, n)
    inc_ids = inc_ids.at[di, rank].set(src_s, mode="drop")
    inc_dist = inc_dist.at[di, rank].set(dist_s, mode="drop")

    # ---- 2. compact affected destination list (static capacity E) ----------
    e = dst_s.shape[0]
    first = jnp.concatenate(
        [jnp.array([True]), dst_s[1:] != dst_s[:-1]]) & (dst_s < n)
    aff_key = jnp.where(first, jnp.arange(e), e)
    aff_pos = jnp.argsort(aff_key)                               # firsts first
    aff = jnp.where(jnp.take(aff_key, aff_pos) < e,
                    jnp.take(dst_s, aff_pos), n)                 # (E,) padded
    aff_mask = aff < n
    aff_safe = jnp.where(aff_mask, aff, 0)

    # ---- 3. merged candidate lists: old N(v) + incoming --------------------
    cand_ids = jnp.concatenate(
        [adj_ids[aff_safe], inc_ids[aff_safe]], axis=-1)         # (E, mx+k_in)
    cand_dist = jnp.concatenate(
        [adj_dist[aff_safe], inc_dist[aff_safe]], axis=-1)
    # Dedup repeated ids (u may already be a neighbor of v): keep first.
    eq = cand_ids[:, :, None] == cand_ids[:, None, :]
    c = cand_ids.shape[1]
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    dup = jnp.any(eq & tri[None] & (cand_ids != INVALID)[:, :, None], axis=-1)
    cvalid = (cand_ids != INVALID) & ~dup & aff_mask[:, None]
    cand_dist = jnp.where(cvalid, cand_dist, jnp.inf)
    cand_ids_m = jnp.where(cvalid, cand_ids, INVALID)
    srt = jnp.argsort(cand_dist, axis=-1)
    cand_ids_m = jnp.take_along_axis(cand_ids_m, srt, axis=-1)
    cand_dist = jnp.take_along_axis(cand_dist, srt, axis=-1)
    cvalid = jnp.take_along_axis(cvalid, srt, axis=-1)
    n_cand = jnp.sum(cvalid, axis=-1)

    # ---- 4. overflow rows re-prune (Alg. 2); others append -----------------
    overflow = n_cand > m_limit                                  # (E,)
    pd = pairwise_candidate_dist(data, cand_ids_m, metric)
    pruned = rng_prune(cand_ids_m, cand_dist, pd, cvalid & overflow[:, None],
                       m_limit, alpha, None, m_max=m_max)
    app_ids = jnp.where(cvalid, cand_ids_m, INVALID)[:, :m_max]
    app_dist = jnp.where(cvalid, cand_dist, jnp.inf)[:, :m_max]
    new_ids = jnp.where(overflow[:, None], pruned.ids, app_ids)
    new_dist = jnp.where(overflow[:, None], pruned.dist, app_dist)

    wr = jnp.where(aff_mask, aff, n)
    adj_ids = adj_ids.at[wr].set(new_ids, mode="drop")
    adj_dist = adj_dist.at[wr].set(new_dist, mode="drop")
    return ReverseResult(adj_ids, adj_dist, pruned.n_checks, n_dropped)


def commit_group(
    data,
    adj_ids,      # int32[m, n, M_max] stacked adjacency (one graph per row)
    adj_dist,     # float32[m, n, M_max]
    src,          # int32[b] inserted nodes
    pruned,       # list[PruneResult] per graph, from multi_prune
    row_mask,     # bool[b]
    m_limits,     # int32[m] per-graph out-degree limits
    alphas,       # float32[m]
    *,
    k_in: int,
    m_max: int,
    metric: str = "l2",
):
    """Forward + reverse commit for all m graphs of one insertion batch.

    The scatter_rows -> add_reverse_edges loop every multi-builder runs
    after multi_prune, factored out so HNSW / Vamana / NSG share one
    implementation.  Pure in its accounting — returns
    ``(new_ids, new_dist, n_checks)`` where ``n_checks`` is the total
    reverse-prune dominance-check count as an int32 device scalar (callers
    log it on a CounterTape; it increments prune AND prune_base since the
    reverse commit has no EPO sharing) — so the whole step stays traceable
    inside the fused batch dispatch (DESIGN.md §12).
    """
    new_ids, new_dist = adj_ids, adj_dist
    n_checks = jnp.int32(0)
    for i, pr in enumerate(pruned):
        ai, ad = scatter_rows(new_ids[i], new_dist[i], src, pr.ids, pr.dist,
                              row_mask)
        rev = add_reverse_edges(
            data, ai, ad, src, pr.ids, pr.dist, row_mask,
            m_limits[i], alphas[i], k_in=k_in, m_max=m_max, metric=metric)
        n_checks += rev.n_checks
        new_ids = new_ids.at[i].set(rev.adj_ids)
        new_dist = new_dist.at[i].set(rev.adj_dist)
    return new_ids, new_dist, n_checks
