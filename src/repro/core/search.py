"""Batched lockstep beam search — Algorithm 1 (KANNS) and Algorithm 3 (mKANNS).

TPU adaptation of the paper's per-query priority-queue search: a whole batch
of ``b`` queries searches ``m`` graphs simultaneously inside one
``lax.while_loop``.  Pools are fixed-size sorted arrays (``ef_max`` slots);
each hop expands the ``W`` closest unexpanded pool entries per
(query, graph) (``expand_width``, DESIGN.md §10), gathers their
out-neighbors, computes distances through the V_delta-aware kernel and
merges by a sorted-pool ⊕ top-k candidate merge.

Multi-expansion (``expand_width``, DESIGN.md §10): W = 1 is the paper's
sequential best-first schedule — builders and the estimation path pin it so
§2.1 bit-identity and the paper-exact #dist counters hold.  W > 1 expands a
W-wide frontier per hop, cutting the ``while_loop`` trip count ~W× and
amortizing every fixed per-hop cost (pool merge, hash probing, kernel
dispatch) — the serving default (serve/retrieval.py uses W = 4).

ESO (shared V_delta cache): with ``share_cache=True`` a per-query membership
structure is shared by all m graphs — exactly the paper's Alg. 3 cache.  The
*total* number of computed distances equals the size of the union of
(query, neighbor) pairs any graph visits, independent of visit order, so the
lockstep schedule reports the same #dist as the paper's sequential one.

Visited/V_delta representation (``visited_impl``, DESIGN.md §9):
  "dense"  bool[b, m, n] visit bitmap + bool[b, n] V_delta has-bit.  Exact
           membership, exact #dist counters, O(n) memory per query — the
           builder/estimation default (§2.1 bit-identity).
  "hash"   fixed-size open-addressing hash sets (core/hashset.py): int32
           keys, power-of-two slots sized from the hop bound × per-hop
           candidate width (W·Mx), windowed linear probing in-loop.
           O(ef·W·M·hops) memory per query independent of n — the serving
           default.  No false positives; overflow degrades to revisits, so
           hash-mode counters upper-bound dense counters.

Counters (paper metrics):
  n_fresh    — distances each graph would compute alone (no sharing): the
               per-graph Algorithm-1 cost, summed over graphs.
  n_computed — distances actually computed (cache misses). Equal to n_fresh
               when share_cache=False.
  With W > 1 both counters count the W-wide schedule's work, which can
  exceed the sequential schedule's (DESIGN.md §10).

Per-graph pool sizes ``ef_i <= ef_max`` are enforced by slot masks; because
pools are kept globally sorted and entries only move backwards, masking slots
``j >= ef_i`` is equivalent to hard eviction (see tests/test_search.py).

The search is metric-generic (DESIGN.md §4): ``metric`` selects the distance
the pools rank by; builders pass the kernel form ("l2"/"ip") over prepared
data so the loop never normalizes, while external callers may pass "cosine"
and get one in-jit normalization per call.

Corpora beyond one device shard (DESIGN.md §11): ``sharded_knn_search``
runs this same loop per shard of a ``graph.ShardedGraph`` under a
``shard_map`` over the ``"shard"`` mesh axis, restores global ids, and
merges per-shard pools with ``_merge_topk`` — scatter-gather partitioned
search with the single-shard case bit-identical to ``knn_search``.
``routed_shards=p`` (DESIGN.md §13) turns the scatter-gather into a
routed search: each query scores the partition centroids with the same
metric kernels, searches only its top-p shards (``route_topk``), and the
per-shard query blocks are compacted host-side into static bucketed
shapes so every device only searches queries routed to it.
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import graph as graph_lib
from repro.core import hashset
from repro.core import metric as metric_lib
from repro.core.graph import INVALID
from repro.distributed import sharding as sharding_lib
from repro.kernels import ops

VISITED_IMPLS = ("dense", "hash")


class SearchResult(NamedTuple):
    pool_ids: jax.Array    # int32[b, m, ef_max] ascending by distance
    pool_dist: jax.Array   # float32[b, m, ef_max]
    n_fresh: jax.Array     # int32[] per-graph-alone distance count
    n_computed: jax.Array  # int32[] actually computed (ESO)
    hops: jax.Array        # int32[]
    cache_d: jax.Array     # float32[b, n] V_delta (or [b, 1] dummy)
    cache_has: jax.Array   # bool[b, n] dense | int32[b, S] hash key table


def fresh_cache(b: int, n: int, share_cache: bool,
                visited_impl: str = "dense", *, slots: int | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Empty V_delta — 'initialize V_delta as -1 for each vector' (Alg. 5 l.7).

    Only membership is materialized (see _expand_all_graphs); cache_d is a
    dummy kept for API stability.  In hash mode (DESIGN.md §9) membership
    is an int32[b, slots] open-addressing key table instead of bool[b, n];
    callers carrying the cache across calls size ``slots`` once via
    ``hashset.auto_slots``."""
    dummy = jnp.zeros((b, 1), jnp.float32)
    if share_cache and visited_impl == "hash":
        return (dummy, hashset.make_tables(
            (b,), slots or hashset.CACHE_SLOTS_CAP >> 4))
    w = n if share_cache else 1
    return (dummy, jnp.zeros((b, w), bool))


def _first_occurrence(ids: jax.Array, sentinel: int) -> jax.Array:
    """bool[..., k]: True at the first occurrence of each id (flat order).

    Sort-based: O(k log k) per row, vectorized (the in-hop cross-graph dedup
    that makes the lockstep schedule's V_delta accounting match the paper's
    sequential one — §Perf iteration 4)."""
    k = ids.shape[-1]
    pos = jnp.broadcast_to(jnp.arange(k), ids.shape)
    order = jnp.argsort(ids, axis=-1)
    s_ids = jnp.take_along_axis(ids, order, axis=-1)
    s_pos = jnp.take_along_axis(pos, order, axis=-1)
    first_sorted = jnp.concatenate(
        [jnp.ones_like(s_ids[..., :1], bool),
         s_ids[..., 1:] != s_ids[..., :-1]], axis=-1)
    inv = jnp.argsort(s_pos, axis=-1)
    return jnp.take_along_axis(first_sorted, inv, axis=-1)


def _merge_topk(pool_ids, pool_dist, expanded, cand_ids, cand_dist):
    """Sorted-pool ⊕ top-k candidate merge (§Perf iteration 6).

    The pool is already sorted ascending, so only the candidates need a
    partial sort: ``lax.top_k`` keeps the ``min(kx, ef_max)`` closest
    (ties prefer lower index — the flat candidate order), then a rank-based
    two-way merge places every survivor, materialized with gathers only
    (scatters serialize on CPU and copy on accelerators).  Byte-equivalent
    to the stable full-argsort merge over the (ef_max + kx)-wide
    concatenation it replaces (pool entries win distance ties; verified
    adversarially in tests/test_multi_expand.py), at O(ef·kc) compare work
    instead of O((ef + kx)·log(ef + kx)) sort work per (query, graph).

    Args:
      pool_ids/pool_dist/expanded: (..., ef_max) sorted pools.
      cand_ids/cand_dist: (..., kx) candidates (INVALID/inf where masked).
    Returns the merged (pool_ids, pool_dist, expanded).
    """
    ef_max = pool_ids.shape[-1]
    kx = cand_ids.shape[-1]
    kc = min(kx, ef_max)
    negd, order = jax.lax.top_k(-cand_dist, kc)
    c_dist = -negd
    c_ids = jnp.take_along_axis(cand_ids, order, axis=-1)
    # Each pool entry's merged rank, with the stable tie rule of the old
    # concat-argsort (pool slots preceded candidates in the concatenation,
    # so a pool entry outranks an equal-distance candidate).
    cand_lt = c_dist[..., None, :] < pool_dist[..., :, None]   # (..., ef, kc)
    rank_pool = jnp.arange(ef_max) + jnp.sum(cand_lt, axis=-1)
    # Invert by gathering: output slot r holds pool[i] iff some pool entry
    # has rank r (i = #pool ranks < r, strictly increasing), else candidate
    # j = r - i — the j-th candidate is the only unplaced element left.
    rr = jnp.arange(ef_max)
    i_r = jnp.sum(rank_pool[..., None, :] < rr[:, None], axis=-1)
    i_safe = jnp.minimum(i_r, ef_max - 1)
    is_pool = jnp.take_along_axis(rank_pool, i_safe, axis=-1) == rr
    j_safe = jnp.clip(rr - i_r, 0, kc - 1)
    out_ids = jnp.where(is_pool,
                        jnp.take_along_axis(pool_ids, i_safe, axis=-1),
                        jnp.take_along_axis(c_ids, j_safe, axis=-1))
    out_dist = jnp.where(is_pool,
                         jnp.take_along_axis(pool_dist, i_safe, axis=-1),
                         jnp.take_along_axis(c_dist, j_safe, axis=-1))
    # candidates enter unexpanded
    out_exp = jnp.where(is_pool,
                        jnp.take_along_axis(expanded, i_safe, axis=-1),
                        False)
    return out_ids, out_dist, out_exp


def apply_tombstones(pool_ids, pool_dist, tomb_ids):
    """Mask tombstoned ids out of a sorted pool at merge time (DESIGN.md §15).

    ``tomb_ids`` is int32[..., T], INVALID-padded (the padding can never
    match a pool entry: the equality is guarded on ``pool_ids != INVALID``,
    and live tombstones are real ids >= 0).  Matching slots become
    INVALID/inf and are pushed behind every survivor by a stable argsort on
    the dead flag — survivors keep their relative (ascending-distance,
    bit-pinned tie) order, so the result is exactly the pool a search that
    never saw the deleted nodes would have *ranked*, over the candidates
    this search visited.  Applied to the full ef-wide pool BEFORE any k
    truncation, so the ef − k slack refills the top-k with the next-best
    live candidates (tests/test_streaming.py pins the refill).
    """
    hit = jnp.any(pool_ids[..., :, None] == tomb_ids[..., None, :], axis=-1)
    dead = hit & (pool_ids != INVALID)
    pool_ids = jnp.where(dead, INVALID, pool_ids)
    pool_dist = jnp.where(dead, jnp.inf, pool_dist)
    order = jnp.argsort(dead, axis=-1, stable=True)   # False (live) first
    return (jnp.take_along_axis(pool_ids, order, axis=-1),
            jnp.take_along_axis(pool_dist, order, axis=-1))


def _corpus_len(data) -> int:
    """Corpus row count of either representation the search accepts:
    a bare f32[n, d] array, or a ``metric.QuantizedData`` (DESIGN.md §16)."""
    if isinstance(data, metric_lib.QuantizedData):
        return data.codes.shape[0]
    return data.shape[0]


def _gathered_distance(data, flat_ids, queries, metric):
    """Per-query gathered distances under either corpus representation.

    fp32 gathers the vectors and dispatches the V_delta-aware kernel;
    SQ8 gathers int8 codes + precomputed dequantized norms and dispatches
    the quantized form (ops.gather_distance_q) — same (b, k) f32 output,
    priced against the dequantized corpus (DESIGN.md §16).
    """
    if isinstance(data, metric_lib.QuantizedData):
        ccodes = data.codes[flat_ids]                    # (b, k, d) int8
        cn = data.norms[flat_ids]                        # (b, k)
        return ops.gather_distance_q(queries, ccodes, data.scale, cn,
                                     metric=metric)
    cvec = data[flat_ids]                                # (b, k, d)
    return ops.gather_distance(queries, cvec, metric=metric)


def rerank_pool(queries, data, pool_ids, *, metric):
    """Re-rank a quantized-search pool against the fp32 corpus.

    The ef-wide pool a ``QuantizedData`` beam search returns ranks by
    distances to the *dequantized* corpus; before any k truncation the
    surviving candidates are re-priced against the full-precision keys and
    stably re-sorted (DESIGN.md §16).  INVALID slots keep +inf and sink;
    ties keep the quantized-pool order (stable argsort).  Returns
    (pool_ids, pool_dist, n_rerank) where ``n_rerank`` counts the fp32
    distances computed — the quantized path's extra #dist, added to
    ``n_computed`` but not ``n_fresh`` (re-pricing visited nodes computes
    distances without visiting anything new).
    """
    valid = pool_ids != INVALID
    cvec = data[jnp.maximum(pool_ids, 0)]                # (b, ef, d)
    dist = ops.gather_distance(
        queries, cvec, cached=jnp.full(pool_ids.shape, jnp.inf, jnp.float32),
        mask=valid, metric=metric)
    order = jnp.argsort(dist, axis=-1, stable=True)
    return (jnp.take_along_axis(pool_ids, order, axis=-1),
            jnp.take_along_axis(dist, order, axis=-1),
            jnp.sum(valid).astype(jnp.int32))


def _expand_all_graphs(graph_ids, data, queries, query_ids, row_mask,
                       slot_mask, pool_ids, pool_dist, expanded,
                       visited, cache_d, cache_has, share_cache, metric,
                       width):
    """One hop of ALL m graphs, fully vectorized over (b, m, W).

    The ``width`` closest unexpanded pool entries per (query, graph) expand
    together (W = 1 is the sequential best-first schedule); their W·Mx
    candidate neighbors are deduplicated in-row, and cross-graph duplicates
    within the hop are deduplicated by first occurrence in graph order, so
    with W = 1 the computed-distance counter equals the sequential
    schedule's |union| exactly (DESIGN.md §10 for W > 1 semantics).

    ``visited`` is either the dense bool[b, m, n] bitmap or an int32
    [b, m, S] hash-key table (dispatch on dtype; DESIGN.md §9), and
    ``cache_has`` likewise bool[b, n] or int32[b, S'].
    """
    b, m, ef_max = pool_ids.shape
    n = _corpus_len(data)
    mx = graph_ids.shape[2]
    kx = width * mx
    brange = jnp.arange(b)
    mrange = jnp.arange(m)
    hash_visited = visited.dtype != jnp.bool_

    unexp = (pool_ids != INVALID) & (~expanded) & slot_mask[None]
    # W closest unexpanded slots: the pool is sorted ascending, so these are
    # the first W unexpanded slot positions (top_k of the negated position;
    # position ef_max = "no slot" sentinel).
    slot_pos = jnp.where(unexp, jnp.arange(ef_max), ef_max)
    neg_sel, _ = jax.lax.top_k(-slot_pos, width)
    sel = -neg_sel                                               # (b, m, W)
    act = (sel < ef_max) & row_mask[:, None, None]               # (b, m, W)
    sel_safe = jnp.minimum(sel, ef_max - 1)
    u = jnp.take_along_axis(pool_ids, sel_safe, axis=-1)         # (b, m, W)
    u_safe = jnp.where(act, jnp.maximum(u, 0), 0)
    expanded = expanded.at[brange[:, None, None], mrange[None, :, None],
                           jnp.where(act, sel_safe, ef_max)].set(
        True, mode="drop")

    nbrs = graph_ids[mrange[None, :, None], u_safe]           # (b, m, W, Mx)
    nbrs = nbrs.reshape(b, m, kx)
    nbrs_safe = jnp.maximum(nbrs, 0)
    # same-id duplicates within one (query, graph) hop count/insert once
    # (small W·Mx: a triangular compare beats a sort here).  Compared on
    # the raw ids: clamped INVALID lanes would alias node 0 and discard a
    # genuine id-0 candidate arriving after padding.
    eq = nbrs[..., :, None] == nbrs[..., None, :]
    tri = jnp.tril(jnp.ones((kx, kx), bool), k=-1)
    dup = jnp.any(eq & tri[None, None], axis=-1)
    act_flat = jnp.repeat(act, mx, axis=-1)                      # (b, m, kx)
    prelim = ((nbrs != INVALID) & act_flat
              & (nbrs != query_ids[:, None, None]) & ~dup)
    if hash_visited:
        visited, vis, _ = hashset.lookup_insert(visited, nbrs_safe, prelim)
        # Overflow guard: a dropped insert can re-propose a node that is
        # already pooled; dense mode can't (pool membership implies a set
        # visit bit), so only hash mode pays this compare (DESIGN.md §9).
        in_pool = jnp.any(
            nbrs_safe[..., :, None] == pool_ids[..., None, :], axis=-1)
        valid = prelim & ~vis & ~in_pool
    else:
        vis = visited[brange[:, None, None], mrange[None, :, None],
                      nbrs_safe]
        valid = prelim & ~vis

    flat_ids = nbrs_safe.reshape(b, m * kx)
    flat_valid = valid.reshape(b, m * kx)
    if share_cache and m > 1:
        first = _first_occurrence(
            jnp.where(flat_valid, flat_ids, n + jnp.arange(m * kx)[None, :]),
            n)                                                    # (b, m*kx)
        first = first & flat_valid
    else:
        first = flat_valid

    dists = _gathered_distance(data, flat_ids, queries, metric)
    if share_cache:
        # V_delta's domain is exactly the union of per-graph visit sets, so
        # only membership is tracked; the values come from the batched kernel
        # either way (lockstep hardware computes the tile regardless —
        # DESIGN.md §3, §Perf iteration 5). #dist counters stay exact in
        # dense mode; hash mode upper-bounds them under overflow (§9).
        if cache_has.dtype != jnp.bool_:
            # first-occurrence lanes only: keys distinct within each row.
            cache_has, c_found, _ = hashset.lookup_insert(
                cache_has, flat_ids, first)
            n_comp = jnp.sum(first & ~c_found).astype(jnp.int32)
        else:
            has = cache_has[brange[:, None], flat_ids]
            need = flat_valid & ~has
            scat = jnp.where(need, flat_ids, n)
            cache_has = cache_has.at[brange[:, None], scat].set(
                True, mode="drop")
            n_comp = jnp.sum(need & first).astype(jnp.int32)
    else:
        n_comp = jnp.sum(flat_valid).astype(jnp.int32)
    n_fresh = jnp.sum(flat_valid).astype(jnp.int32)

    if not hash_visited:
        scat_v = jnp.where(flat_valid, flat_ids, n).reshape(b, m, kx)
        visited = visited.at[brange[:, None, None],
                             mrange[None, :, None],
                             scat_v].set(True, mode="drop")

    dists3 = dists.reshape(b, m, kx)
    cand_ids = jnp.where(valid, nbrs, INVALID)
    cand_dist = jnp.where(valid, dists3, jnp.inf)
    pool_ids, pool_dist, expanded = _merge_topk(
        pool_ids, pool_dist, expanded, cand_ids, cand_dist)
    return (pool_ids, pool_dist, expanded, visited, cache_d, cache_has,
            n_fresh, n_comp)


@functools.partial(
    jax.jit,
    static_argnames=("ef_max", "max_hops", "share_cache", "metric",
                     "visited_impl", "hash_slots", "expand_width"))
def beam_search(
    graph_ids: jax.Array,      # int32[m, n, Mx]
    data: jax.Array,           # f32[n, d]
    queries: jax.Array,        # f32[b, d]
    query_ids: jax.Array,      # int32[b]; -1 for external queries
    row_mask: jax.Array,       # bool[b]; False = padding row
    ef: jax.Array,             # int32[m] per-graph pool size
    entry: jax.Array,          # int32[b, m] entry points
    cache_d: jax.Array | None = None,    # carried V_delta (ESO across calls)
    cache_has: jax.Array | None = None,
    *,
    ef_max: int,
    max_hops: int,
    share_cache: bool,
    metric: str = "l2",
    visited_impl: str = "dense",
    hash_slots: int | None = None,
    expand_width: int = 1,
) -> SearchResult:
    if visited_impl not in VISITED_IMPLS:
        raise ValueError(
            f"visited_impl {visited_impl!r} not in {VISITED_IMPLS}")
    if expand_width < 1:
        raise ValueError(f"expand_width must be >= 1, got {expand_width}")
    width = min(expand_width, ef_max)      # cannot expand more than the pool
    met = metric_lib.resolve(metric)
    if met.normalize:
        # One in-jit normalization per call; builders avoid even this by
        # preparing the dataset once and passing the kernel form ("ip").
        # A QuantizedData corpus was normalized BEFORE quantization
        # (Metric.prepare_quantized) — only the queries normalize here.
        if not isinstance(data, metric_lib.QuantizedData):
            data = metric_lib.normalize(data)
        queries = metric_lib.normalize(queries)
    metric = met.kernel
    m, n, mx = graph_ids.shape
    b = queries.shape[0]
    brange = jnp.arange(b)
    slot_mask = jnp.arange(ef_max)[None, :] < ef[:, None]        # (m, ef_max)

    # ---- init: pool[0] = (ep, delta(q, ep)), Alg. 1 line 2 ----------------
    pool_ids = jnp.full((b, m, ef_max), INVALID, jnp.int32)
    pool_dist = jnp.full((b, m, ef_max), jnp.inf, jnp.float32)
    expanded = jnp.zeros((b, m, ef_max), bool)
    if visited_impl == "hash":
        slots = hash_slots or hashset.auto_slots(max_hops, width * mx)
        visited = hashset.make_tables((b, m), slots)
    else:
        visited = jnp.zeros((b, m, n), bool)
    if cache_d is None:
        # The V_delta union absorbs all m graphs' inserts, so a caller-
        # supplied per-(query, graph) hash_slots is scaled by m here.
        cache_slots = (
            min(hashset.next_pow2(m * hash_slots), hashset.CACHE_SLOTS_CAP)
            if hash_slots else
            hashset.auto_slots(max_hops, width * mx, searches=m,
                               cap=hashset.CACHE_SLOTS_CAP))
        cache_d, cache_has = fresh_cache(b, n, share_cache, visited_impl,
                                         slots=cache_slots)
    n_fresh = jnp.int32(0)
    n_comp = jnp.int32(0)

    for i in range(m):
        ep = entry[:, i]
        ep_safe = jnp.maximum(ep, 0)
        ok = (ep != INVALID) & (ep != query_ids) & row_mask
        d0 = _gathered_distance(data, ep_safe[:, None], queries,
                                metric)[:, 0]
        if share_cache:
            if cache_has.dtype != jnp.bool_:
                cache_has, c_found, _ = hashset.lookup_insert(
                    cache_has, ep_safe[:, None], ok[:, None])
                need = ok & ~c_found[:, 0]
            else:
                has = cache_has[brange, ep_safe]
                need = ok & ~has
                scat = jnp.where(need, ep_safe, n)
                cache_has = cache_has.at[brange, scat].set(True, mode="drop")
            n_comp += jnp.sum(need).astype(jnp.int32)
        else:
            n_comp += jnp.sum(ok).astype(jnp.int32)
        n_fresh += jnp.sum(ok).astype(jnp.int32)
        pool_ids = pool_ids.at[:, i, 0].set(jnp.where(ok, ep, INVALID))
        pool_dist = pool_dist.at[:, i, 0].set(jnp.where(ok, d0, jnp.inf))
        if visited_impl == "hash":
            vtab, _, _ = hashset.lookup_insert(
                visited[:, i], ep_safe[:, None], ok[:, None])
            visited = visited.at[:, i].set(vtab)
        else:
            visited = visited.at[brange, i, jnp.where(ok, ep_safe, 0)].set(
                visited[brange, i, jnp.where(ok, ep_safe, 0)] | ok)

    state = (pool_ids, pool_dist, expanded, visited, cache_d, cache_has,
             n_fresh, n_comp, jnp.int32(0))

    def cond(state):
        pool_ids, _, expanded, *_, hop = state
        unexp = (pool_ids != INVALID) & ~expanded & slot_mask[None]
        return (hop < max_hops) & jnp.any(unexp & row_mask[:, None, None])

    def body(state):
        (pool_ids, pool_dist, expanded, visited, cache_d, cache_has,
         n_fresh, n_comp, hop) = state
        (pool_ids, pool_dist, expanded, visited, cache_d, cache_has,
         nf, nc) = _expand_all_graphs(
            graph_ids, data, queries, query_ids, row_mask, slot_mask,
            pool_ids, pool_dist, expanded, visited, cache_d, cache_has,
            share_cache, metric, width)
        return (pool_ids, pool_dist, expanded, visited, cache_d, cache_has,
                n_fresh + nf, n_comp + nc, hop + 1)

    state = jax.lax.while_loop(cond, body, state)
    (pool_ids, pool_dist, _, _, cache_d, cache_has,
     n_fresh, n_comp, hops) = state
    # Mask out slots beyond each graph's ef (they are not part of C(u)).
    pool_ids = jnp.where(slot_mask[None], pool_ids, INVALID)
    pool_dist = jnp.where(slot_mask[None], pool_dist, jnp.inf)
    return SearchResult(pool_ids, pool_dist, n_fresh, n_comp, hops,
                        cache_d, cache_has)


def default_max_hops(ef_max: int, expand_width: int = 1) -> int:
    """Generous hop bound: best-first search converges in ~ef expansions,
    and a width-W hop performs W of them — the bound (and with it the
    auto-sized hash tables, which scale as hops × W·Mx) shrinks ~W×.
    Invalid widths are left to ``beam_search``'s validation."""
    return 3 * -(-ef_max // max(1, expand_width)) + 16


def knn_search(graph_ids: jax.Array, data: jax.Array, queries: jax.Array,
               k: int, ef: int, entry: int | jax.Array,
               max_hops: int | None = None, *,
               metric: str = "l2",
               visited_impl: str = "dense",
               hash_slots: int | None = None,
               expand_width: int = 1,
               row_mask: jax.Array | None = None,
               tombstone_ids: jax.Array | None = None,
               quantize: str = "none",
               quant: metric_lib.QuantizedData | None = None) -> SearchResult:
    """Single-graph external k-ANNS (evaluation path, Alg. 1).

    ``metric`` must match the metric the graph was built under; pool
    distances come back in that metric's units (core/metric.py convention).
    ``visited_impl="hash"`` swaps the dense visit bitmap for the O(ef)
    hash-set state (DESIGN.md §9) — the serving default via
    serve/retrieval.py.  ``expand_width`` expands that many frontier nodes
    per hop (DESIGN.md §10); 1 reproduces the paper's sequential schedule,
    serving uses 4.  ``row_mask`` marks padding rows that must do no
    search work (static-shape batching; their pools come back INVALID).
    ``tombstone_ids`` (int32[T], INVALID-padded) masks deleted nodes out of
    the ef-wide pool before the k truncation (``apply_tombstones``,
    DESIGN.md §15); ``None`` dispatches the exact program of before the
    parameter existed.

    ``quantize="sq8"`` (DESIGN.md §16) beam-searches the int8 ``quant``
    corpus (a ``metric.QuantizedData`` over the same prepared vectors as
    ``data`` — ``Metric.prepare_quantized``) and re-ranks the final
    ef-wide pool against the fp32 ``data`` before the k truncation
    (``rerank_pool``); the re-rank's fp32 distances add to ``n_computed``
    while ``n_fresh`` keeps its paper-exact beam accounting.  The default
    ``"none"`` dispatches the exact fp32 program of before the knob
    existed.
    """
    if quantize not in metric_lib.QUANTIZE_MODES:
        raise ValueError(
            f"quantize {quantize!r} not in {metric_lib.QUANTIZE_MODES}")
    if quantize == "sq8" and quant is None:
        raise ValueError(
            "quantize='sq8' needs the quantized corpus: pass "
            "quant=metric.QuantizedData (Metric.prepare_quantized over the "
            "same vectors as data; retrieval.build_index(quantize='sq8') "
            "stores one on the index)")
    if k > ef:
        raise ValueError(
            f"k={k} > ef={ef}: the search pool holds only ef candidates, so "
            f"slots beyond ef would be INVALID padding, silently returning "
            f"fewer than k real neighbors; raise ef to at least k")
    if graph_ids.ndim == 2:
        graph_ids = graph_ids[None]
    if tombstone_ids is not None:
        tombstone_ids = jnp.asarray(tombstone_ids, jnp.int32)
        if tombstone_ids.ndim != 1:
            raise ValueError(
                f"tombstone_ids must be a 1-D id array, got shape "
                f"{tombstone_ids.shape}")
        if tombstone_ids.shape[0] == 0:
            tombstone_ids = None       # empty: skip the mask entirely
    b = queries.shape[0]
    ep = jnp.broadcast_to(jnp.asarray(entry, jnp.int32), (b,))[:, None]
    res = beam_search(
        graph_ids, quant if quantize == "sq8" else data, queries,
        jnp.full((b,), INVALID, jnp.int32),
        jnp.ones((b,), bool) if row_mask is None else row_mask,
        jnp.array([ef], jnp.int32), ep,
        ef_max=ef, max_hops=max_hops or default_max_hops(ef, expand_width),
        share_cache=False, metric=metric, visited_impl=visited_impl,
        hash_slots=hash_slots, expand_width=expand_width)
    pool_i, pool_d = res.pool_ids[:, 0], res.pool_dist[:, 0]
    n_comp = res.n_computed
    if quantize == "sq8":
        pool_i, pool_d, n_rr = rerank_pool(queries, data, pool_i,
                                           metric=metric)
        n_comp = n_comp + n_rr
    if tombstone_ids is not None:
        pool_i, pool_d = apply_tombstones(pool_i, pool_d, tombstone_ids)
    return SearchResult(pool_i[:, :k], pool_d[:, :k],
                        res.n_fresh, n_comp, res.hops,
                        res.cache_d, res.cache_has)


# ---------------------------------------------------------------------------
# Mesh-partitioned scatter-gather search (DESIGN.md §11).
# ---------------------------------------------------------------------------

def _shard_search_body(graph_ids, data, global_ids, entries, shard_mask,
                       queries, row_mask, *quant, ef, max_hops, metric,
                       visited_impl, hash_slots, expand_width):
    """Search every shard of one mesh slot's block; merge its pools locally.

    Runs inside ``shard_map``: arguments carry this slot's ``s_loc``
    contiguous shards.  Each shard runs the *unchanged* lockstep beam
    search (W, metric, dense/hash visited state all preserved) on its
    local-id subgraph with full pool size ``ef`` — scatter-gather explores
    each partition as deeply as the unsharded search explores the whole
    corpus, which is where the recall of the merged result comes from.
    Pool ids are restored to global ids *before* any merge (a local id is
    meaningless outside its shard), then folded left-to-right in shard
    order through the rank merge; counters psum over the mesh so every
    slot returns the global totals.

    ``*quant`` (DESIGN.md §16), when present, is this slot's
    ``(qcodes, qscale, qnorms)`` SQ8 block: each shard then beam-searches
    its int8 codes and re-ranks its local ef-pool against its fp32
    ``data[s]`` *before* the global-id restore and the fold, so every
    distance that crosses a merge is an fp32 distance and the folded pool
    stays rank-merge-sorted.  The re-rank counts add to ``n_comp``.

    ``shard_mask`` (bool[s_loc], DESIGN.md §14) is this slot's view of the
    shard liveness mask: a dead shard searches with an all-False row mask,
    which is beam_search's zero-work state — its pool comes back all
    INVALID/inf (rank-merging it is a no-op), its counters are 0 (so the
    psum'd totals count live shards only), and its hop count is 0 (so
    pmax reflects the slowest *live* shard).
    """
    s_loc = graph_ids.shape[0]
    b = queries.shape[0]
    qids = jnp.full((b,), INVALID, jnp.int32)
    pool_i = pool_d = None
    n_fresh = n_comp = hops = jnp.int32(0)
    for s in range(s_loc):
        sdata = (metric_lib.QuantizedData(quant[0][s], quant[1][s],
                                          quant[2][s])
                 if quant else data[s])
        ep = jnp.broadcast_to(entries[s].astype(jnp.int32), (b,))[:, None]
        res = beam_search(
            graph_ids[s][None], sdata, queries, qids,
            row_mask & shard_mask[s],
            jnp.array([ef], jnp.int32), ep,
            ef_max=ef, max_hops=max_hops, share_cache=False, metric=metric,
            visited_impl=visited_impl, hash_slots=hash_slots,
            expand_width=expand_width)
        lids = res.pool_ids[:, 0]                              # (b, ef) local
        dist = res.pool_dist[:, 0]
        if quant:
            lids, dist, n_rr = rerank_pool(queries, data[s], lids,
                                           metric=metric)
            n_comp += n_rr
        gids = jnp.where(lids == INVALID, INVALID,
                         global_ids[s][jnp.maximum(lids, 0)])
        if pool_i is None:
            pool_i, pool_d = gids, dist
        else:
            pool_i, pool_d, _ = _merge_topk(
                pool_i, pool_d, jnp.zeros_like(pool_i, bool), gids, dist)
        n_fresh += res.n_fresh
        n_comp += res.n_computed
        hops = jnp.maximum(hops, res.hops)
    n_fresh = jax.lax.psum(n_fresh, "shard")
    n_comp = jax.lax.psum(n_comp, "shard")
    hops = jax.lax.pmax(hops, "shard")
    return pool_i[None], pool_d[None], n_fresh, n_comp, hops


@functools.lru_cache(maxsize=None)
def _sharded_search_fn(mesh, *, k, ef, max_hops, metric, visited_impl,
                       hash_slots, expand_width, tombstones=False,
                       quantize=False):
    """jit'd mesh-partitioned search, cached per (mesh, static knobs).

    ``tombstones=True`` compiles a variant taking one extra trailing
    ``tomb_ids`` argument, masked into the folded ef-wide pool before the
    k truncation (``apply_tombstones``, DESIGN.md §15).  ``quantize=True``
    compiles the SQ8 variant (DESIGN.md §16): three shard-sharded trailing
    arguments ``(qcodes, qscale, qnorms)`` *before* any ``tomb_ids``.  The
    all-False variant is byte-for-byte the program of before the flags
    existed — the healthy fp32 no-delete serving path stays the
    bit-identical cached program.
    """
    body = functools.partial(
        _shard_search_body, ef=ef, max_hops=max_hops, metric=metric,
        visited_impl=visited_impl, hash_slots=hash_slots,
        expand_width=expand_width)
    n_quant = 3 if quantize else 0
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P("shard"), P("shard"), P("shard"), P("shard"),
                  P("shard"), P(), P()) + (P("shard"),) * n_quant,
        out_specs=(P("shard"), P("shard"), P(), P(), P()),
        check_rep=False)

    @jax.jit
    def run(graph_ids, data, global_ids, entries, shard_mask, queries,
            row_mask, *extra):
        blocks_i, blocks_d, n_fresh, n_comp, hops = sharded(
            graph_ids, data, global_ids, entries, shard_mask, queries,
            row_mask, *extra[:n_quant])
        tomb = extra[n_quant:]
        # Fold the per-slot pools in slot order: slots hold contiguous
        # shard blocks, and each block was itself folded in shard order, so
        # the tie precedence is globally (shard, pool rank) — identical to
        # a serial fold over shards 0..S-1 (tests/test_sharded_search.py).
        pool_i, pool_d = blocks_i[0], blocks_d[0]
        for g in range(1, blocks_i.shape[0]):
            pool_i, pool_d, _ = _merge_topk(
                pool_i, pool_d, jnp.zeros_like(pool_i, bool),
                blocks_i[g], blocks_d[g])
        if tombstones:
            pool_i, pool_d = apply_tombstones(pool_i, pool_d, tomb[0])
        return pool_i[:, :k], pool_d[:, :k], n_fresh, n_comp, hops
    return run


# ---------------------------------------------------------------------------
# Query-routed sharded search (DESIGN.md §13).
# ---------------------------------------------------------------------------

# Per-shard query blocks pad up to a multiple of this (graph.bucket) so the
# set of compiled routed-search shapes stays small across batches.  Tighter
# than the serving block multiple of 16: routed per-shard counts are b·p/S
# in expectation, and padding rows still pay full lockstep search work.
ROUTED_BLOCK_MULT = 4


def route_topk(scores: jax.Array, p: int) -> jax.Array:
    """Top-p shard selection from centroid distances (smaller = closer).

    ``scores`` is float[b, S]; returns int32[b, p] shard ids.  Stable
    argsort fixes the tie rule — equal-distance centroids route to the
    LOWER shard id — and the selected ids come back sorted ascending so
    the pool fold visits shards in the same serial order the
    scatter-gather fold uses (tie precedence stays (shard, pool rank)).
    Module-level on purpose: the routing oracle's mutation test swaps it
    (tests/test_oracle.py) the way PR 5's swapped ``_merge_topk``.
    """
    order = jnp.argsort(scores, axis=-1)            # jnp.argsort is stable
    return jnp.sort(order[..., :p].astype(jnp.int32), axis=-1)


def _routed_search_body(graph_ids, data, global_ids, entries, qblocks,
                       qmask, *quant, ef, max_hops, metric, visited_impl,
                       hash_slots, expand_width):
    """Search one mesh slot's shards over their own routed query blocks.

    Runs inside ``shard_map``: this slot's ``s_loc`` shards each receive a
    compacted (Bq, d) block holding ONLY the queries routed to them
    (padding rows masked by ``qmask`` do no search work — beam_search's
    row_mask semantics).  Same unchanged lockstep search per shard as the
    scatter-gather body, same global-id restore before anything leaves the
    shard; but no local fold — each (shard, slot) pool is returned intact
    because a query's p pools live at different slots and merge outside
    the shard_map.  Counters psum over the mesh: since un-routed
    (query, shard) pairs never enter any block, the totals count routed
    work only (DESIGN.md §13).

    ``*quant`` as in ``_shard_search_body`` (DESIGN.md §16): beam over the
    slot's int8 codes, per-shard fp32 re-rank before the global-id
    restore (the fold happens outside the shard_map, so the pools that
    leave this body must already carry fp32 distances).
    """
    s_loc = graph_ids.shape[0]
    bq = qblocks.shape[1]
    qids = jnp.full((bq,), INVALID, jnp.int32)
    outs_i, outs_d = [], []
    n_fresh = n_comp = hops = jnp.int32(0)
    for s in range(s_loc):
        sdata = (metric_lib.QuantizedData(quant[0][s], quant[1][s],
                                          quant[2][s])
                 if quant else data[s])
        ep = jnp.broadcast_to(entries[s].astype(jnp.int32), (bq,))[:, None]
        res = beam_search(
            graph_ids[s][None], sdata, qblocks[s], qids, qmask[s],
            jnp.array([ef], jnp.int32), ep,
            ef_max=ef, max_hops=max_hops, share_cache=False, metric=metric,
            visited_impl=visited_impl, hash_slots=hash_slots,
            expand_width=expand_width)
        lids = res.pool_ids[:, 0]                             # (Bq, ef) local
        dist = res.pool_dist[:, 0]
        if quant:
            lids, dist, n_rr = rerank_pool(qblocks[s], data[s], lids,
                                           metric=metric)
            n_comp += n_rr
        outs_i.append(jnp.where(lids == INVALID, INVALID,
                                global_ids[s][jnp.maximum(lids, 0)]))
        outs_d.append(dist)
        n_fresh += res.n_fresh
        n_comp += res.n_computed
        hops = jnp.maximum(hops, res.hops)
    n_fresh = jax.lax.psum(n_fresh, "shard")
    n_comp = jax.lax.psum(n_comp, "shard")
    hops = jax.lax.pmax(hops, "shard")
    return jnp.stack(outs_i), jnp.stack(outs_d), n_fresh, n_comp, hops


@functools.lru_cache(maxsize=None)
def _routed_search_fn(mesh, *, k, ef, max_hops, metric, visited_impl,
                      hash_slots, expand_width, p, tombstones=False,
                      quantize=False):
    """jit'd routed mesh search, cached per (mesh, static knobs, p).

    ``tombstones`` / ``quantize`` as in ``_sharded_search_fn``: True adds
    a trailing ``tomb_ids`` argument masked into the per-query fold before
    truncation; ``quantize=True`` adds the three shard-sharded SQ8
    arguments ``(qcodes, qscale, qnorms)`` before any ``tomb_ids``.
    """
    body = functools.partial(
        _routed_search_body, ef=ef, max_hops=max_hops, metric=metric,
        visited_impl=visited_impl, hash_slots=hash_slots,
        expand_width=expand_width)
    n_quant = 3 if quantize else 0
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P("shard"),) * (6 + n_quant),
        out_specs=(P("shard"), P("shard"), P(), P(), P()),
        check_rep=False)

    @jax.jit
    def run(graph_ids, data, global_ids, entries, queries, q_index, q_mask,
            routed, slot_of, row_mask, *extra):
        qblocks = queries[q_index]                             # (S, Bq, d)
        blocks_i, blocks_d, n_fresh, n_comp, hops = sharded(
            graph_ids, data, global_ids, entries, qblocks, q_mask,
            *extra[:n_quant])
        tomb = extra[n_quant:]
        # Per-query fold over its p pools: query b's j-th routed shard
        # searched it at (routed[b,j], slot_of[b,j]).  routed rows are
        # sorted ascending, so the fold runs in ascending shard order —
        # the serial tie precedence of the scatter-gather fold.
        pool_i = blocks_i[routed[:, 0], slot_of[:, 0]]         # (b, ef)
        pool_d = blocks_d[routed[:, 0], slot_of[:, 0]]
        for j in range(1, p):
            pool_i, pool_d, _ = _merge_topk(
                pool_i, pool_d, jnp.zeros_like(pool_i, bool),
                blocks_i[routed[:, j], slot_of[:, j]],
                blocks_d[routed[:, j], slot_of[:, j]])
        if tombstones:
            pool_i, pool_d = apply_tombstones(pool_i, pool_d, tomb[0])
        pool_i = jnp.where(row_mask[:, None], pool_i[:, :k], INVALID)
        pool_d = jnp.where(row_mask[:, None], pool_d[:, :k], jnp.inf)
        return pool_i, pool_d, n_fresh, n_comp, hops
    return run


@functools.lru_cache(maxsize=None)
def _fused_routed_search_fn(*, k, ef, max_hops, metric, visited_impl,
                            hash_slots, expand_width, p, tombstones=False,
                            quantize=False):
    """jit'd single-dispatch routed search over the stacked-flat graph.

    The packed execution strategy (DESIGN.md §13): when a mesh slot holds
    more than one shard (always true on a single device), the shard_map
    body's per-shard ``data[s]`` / ``graph_ids[s]`` slices materialize
    O(n/S) copies per shard per call — measured at ~4× the search itself
    at n=1M — and the slot's shards run as serial while loops.  This
    program instead searches ``ShardedGraph.flat_ids``, the precomputed
    block-diagonal adjacency over the concatenated shard rows: every
    routed (query, shard) pair becomes one row of a single ``beam_search``
    whose entry point is that shard's entry in flat id space.  Rows cannot
    escape their shard (the flat graph has no cross-shard edges), so each
    row is bit-identical to the same row of the per-shard search under
    dense visited state, and identical under hash state while the
    auto-sized tables don't overflow (flat vs local ids hash to different
    slots, so overflow — which upper-bounds counters either way, DESIGN.md
    §9 — is the one divergence point).  No per-shard query blocks and no
    padding rows: the row batch is exactly b·p.  Counter semantics match
    the mesh path: one search's totals equal the psum over shards, and its
    hop count is the max over rows = pmax over shards.

    Routing itself (centroid scoring + ``route_topk``) runs inside the jit
    — the mesh path must route on the host to build per-shard blocks, but
    here the routed pairs feed straight into the row batch, so the device
    round-trip would be pure latency.  Same ops, same backend, so both
    paths pick identical shards.  (Consequence: a monkeypatched
    ``route_topk`` only affects this path's freshly-compiled entries — the
    oracle's mutation test targets the host-routed mesh path.)

    ``quantize=True`` (DESIGN.md §16): three trailing SQ8 arguments
    ``(qcodes (S,n_s,d) int8, qscale (S,d) replicated global scale,
    qnorms (S,n_s))`` before any ``tomb_ids``; the b·p-row beam runs over
    the flattened codes and every row's ef-pool re-ranks against the fp32
    flat data before the per-query fold, so folded distances are fp32.
    """
    met = metric_lib.resolve(metric)
    n_quant = 3 if quantize else 0

    @jax.jit
    def run(flat_ids, data, global_ids, entries, centroids, shard_mask,
            queries, row_mask, *extra):
        quant, tomb = extra[:n_quant], extra[n_quant:]
        b = queries.shape[0]
        n_s, d = data.shape[1], data.shape[2]
        flat_data = data.reshape(-1, d)                # contiguous: no copy
        flat_gids = global_ids.reshape(-1)
        beam_data = (metric_lib.QuantizedData(
            quant[0].reshape(-1, d), quant[1][0], quant[2].reshape(-1))
            if quantize else flat_data)
        qprep = met.prepare(queries)
        scores = metric_lib.kernel_distance(
            qprep[:, None, :], centroids[None, :, :], met.kernel)
        # Dead shards score +inf, so route_topk never selects one while
        # p <= live count (the caller clamps; DESIGN.md §14).  All-True
        # masks leave scores bit-unchanged — the healthy path stays
        # identical to the unmasked program.
        scores = jnp.where(shard_mask[None, :], scores, jnp.inf)
        routed = route_topk(scores, p)                 # (b, p) ascending
        p_ = routed.shape[1]
        # row r = (query r // p, routed shard r % p), ascending shard order
        # within each query (route_topk sorts), so the pool fold below
        # keeps the serial (shard, pool rank) tie precedence.
        qrows = jnp.repeat(queries, p_, axis=0)                  # (b*p, d)
        ep = (entries[routed] + routed * n_s).reshape(-1)        # flat ids
        rmask = jnp.repeat(row_mask, p_, axis=0)
        res = beam_search(
            flat_ids[None], beam_data, qrows,
            jnp.full((b * p_,), INVALID, jnp.int32), rmask,
            jnp.array([ef], jnp.int32), ep[:, None],
            ef_max=ef, max_hops=max_hops, share_cache=False, metric=metric,
            visited_impl=visited_impl, hash_slots=hash_slots,
            expand_width=expand_width)
        lids = res.pool_ids[:, 0]                           # (b*p, ef) flat
        dist = res.pool_dist[:, 0]
        n_comp = res.n_computed
        if quantize:
            lids, dist, n_rr = rerank_pool(qrows, flat_data, lids,
                                           metric=metric)
            n_comp = n_comp + n_rr
        gpool = jnp.where(lids == INVALID, INVALID,
                          flat_gids[jnp.maximum(lids, 0)]).reshape(b, p_, -1)
        dpool = dist.reshape(b, p_, -1)
        pool_i, pool_d = gpool[:, 0], dpool[:, 0]
        for j in range(1, p):
            pool_i, pool_d, _ = _merge_topk(
                pool_i, pool_d, jnp.zeros_like(pool_i, bool),
                gpool[:, j], dpool[:, j])
        if tombstones:
            pool_i, pool_d = apply_tombstones(pool_i, pool_d, tomb[0])
        pool_i = jnp.where(row_mask[:, None], pool_i[:, :k], INVALID)
        pool_d = jnp.where(row_mask[:, None], pool_d[:, :k], jnp.inf)
        return pool_i, pool_d, res.n_fresh, n_comp, res.hops
    return run


# Warn-once state for the routed_shards > live-shards clamp: holds the
# (num_shards, n_live, p) tuple of the last ShardHealth state that warned.
# A degraded serving loop calls sharded_knn_search per batch — warning on
# every call floods logs with thousands of identical lines — so the clamp
# warns once per state *transition*: repeat calls under the same degraded
# state stay silent, and any unclamped routed call resets the state so the
# next degradation warns again.
_CLAMP_WARNED_STATE: "tuple[int, int, int] | None" = None


def sharded_knn_search(sharded_graph, queries: jax.Array, k: int, ef: int,
                       *, metric: str = "l2", visited_impl: str = "dense",
                       hash_slots: int | None = None, expand_width: int = 1,
                       max_hops: int | None = None,
                       row_mask: jax.Array | None = None,
                       routed_shards: int | None = None,
                       shard_mask=None,
                       tombstone_ids: jax.Array | None = None,
                       quantize: str = "none",
                       mesh=None) -> SearchResult:
    """Scatter-gather k-ANNS over a mesh-partitioned corpus (DESIGN.md §11).

    Each shard of ``sharded_graph`` (graph.partition) searches its own
    subgraph with the full ``ef`` pool via the unchanged ``beam_search``
    (so ``metric`` / ``visited_impl`` / ``expand_width`` mean exactly what
    they mean unsharded); per-shard pools come back in shard-local ids,
    are restored to global ids, and merge through the same rank merge the
    in-loop pool update uses (``_merge_topk`` — earlier shards win
    distance ties, matching a serial fold).  Counter semantics: ``n_fresh``
    / ``n_computed`` are psum-reduced totals over all shards (the cost of
    the scatter-gather schedule: every shard pays its own search);
    ``hops`` is the max over shards (shards run in parallel, so the
    slowest shard bounds latency).

    With ``num_shards == 1`` the decomposition is trivial and the result
    is bit-identical to ``knn_search`` from the same entry point (pinned
    by test); the default mesh places num_shards / n_devices shards per
    device (distributed.sharding.search_mesh).

    ``routed_shards=p`` (DESIGN.md §13) searches only each query's top-p
    shards by centroid distance (``route_topk`` — stable: ties go to the
    lower shard id), and each query's p pools fold through ``_merge_topk``
    in ascending shard order.  Counters then total the routed work only.
    Two execution strategies, selected by mesh shape: with one device per
    shard, queries are compacted host-side into one static bucketed block
    per shard (padding rows masked, ROUTED_BLOCK_MULT) so each device only
    searches queries routed to it (shard_map); with shards packed many-per-
    device (any single-device run), the routed pairs instead become the
    b·p rows of ONE beam search over the precomputed block-diagonal flat
    graph (``ShardedGraph.flat_ids``) — same results row-for-row, none of
    the per-shard slice copies (``_fused_routed_search_fn``).  ``p == S``
    routes every query to every shard — the scatter-gather decomposition
    exactly — and dispatches the scatter-gather program itself, so it is
    bit-identical to ``routed_shards=None`` by construction.

    ``shard_mask`` (bool[S], DESIGN.md §14) marks live shards for
    degraded-mode serving: dead shards are excluded from BOTH routing
    (their centroid scores mask to +inf so ``route_topk`` never picks
    them) and the merge (their scatter-gather pools search under an
    all-False row mask, returning INVALID/inf that rank-merge as no-ops),
    and the psum'd counters count live-shard work only.  An all-False
    mask raises (no live shard can answer); ``routed_shards`` above the
    live count clamps down with a warning.  ``shard_mask=None`` (and any
    all-True mask) is the healthy path, bit-identical to not having the
    parameter.

    ``tombstone_ids`` (int32[T] global ids, INVALID-padded, DESIGN.md §15)
    masks deleted nodes out of the final folded ef-wide pool before the k
    truncation — on every execution strategy, so a deleted id never
    surfaces even while still a node of some shard's graph.  ``None`` (and
    an empty array) dispatches the exact cached program of before the
    parameter existed (static ``tombstones=False`` variant).

    ``quantize="sq8"`` (DESIGN.md §16) beam-searches each shard's int8
    codes (``ShardedGraph.qcodes`` / ``qscale`` / ``qnorms`` — stored by
    ``graph.partition(..., quantize="sq8")``) and re-ranks every per-shard
    ef-pool against that shard's fp32 rows *before* the global-id restore
    and the fold, on all three execution strategies; re-rank distances add
    to ``n_computed``.  ``"none"`` dispatches the exact fp32 cached
    program of before the knob existed (static ``quantize=False``
    variant).
    """
    if k > ef:
        raise ValueError(
            f"k={k} > ef={ef}: the search pool holds only ef candidates, so "
            f"slots beyond ef would be INVALID padding, silently returning "
            f"fewer than k real neighbors; raise ef to at least k")
    if visited_impl not in VISITED_IMPLS:
        raise ValueError(
            f"visited_impl {visited_impl!r} not in {VISITED_IMPLS}")
    if quantize not in metric_lib.QUANTIZE_MODES:
        raise ValueError(
            f"quantize {quantize!r} not in {metric_lib.QUANTIZE_MODES}")
    if quantize == "sq8" and getattr(sharded_graph, "qcodes", None) is None:
        raise ValueError(
            "quantize='sq8' needs per-shard int8 codes but this "
            "ShardedGraph has none — rebuild it with "
            "graph.partition(..., quantize='sq8') (or "
            "retrieval.build_index(quantize='sq8')), which stores them")
    if expand_width < 1:
        raise ValueError(f"expand_width must be >= 1, got {expand_width}")
    if row_mask is not None:
        row_mask = jnp.asarray(row_mask)
        if row_mask.dtype != jnp.bool_:
            raise ValueError(
                f"row_mask dtype {row_mask.dtype} must be bool: integer "
                f"masks silently cast inside the search (0/1 arithmetic "
                f"instead of validity), so a wrong-dtype mask would search "
                f"padding rows; pass a bool array")
    num_shards = sharded_graph.num_shards
    import numpy as np       # host-side mask validation + routing below
    if shard_mask is not None:
        shard_mask = np.asarray(shard_mask)
        if shard_mask.dtype != np.bool_:
            raise ValueError(
                f"shard_mask dtype {shard_mask.dtype} must be bool: an "
                f"integer mask would silently cast inside the search; pass "
                f"a bool array (True = shard alive)")
        if shard_mask.shape != (num_shards,):
            raise ValueError(
                f"shard_mask shape {shard_mask.shape} must be "
                f"({num_shards},): one liveness flag per shard of this "
                f"ShardedGraph")
        if bool(shard_mask.all()):
            shard_mask = None           # healthy: identical program + args
        elif not bool(shard_mask.any()):
            raise ValueError(
                f"shard_mask is all-False: every one of the {num_shards} "
                f"shards is marked dead, so no shard can answer the query "
                f"— an all-INVALID pool would be silently softmaxed by "
                f"retrieval attention.  Refusing to search; restore at "
                f"least one shard (ShardHealth.revive) or swap in a "
                f"snapshot (serve.resilience)")
    n_live = int(shard_mask.sum()) if shard_mask is not None else num_shards
    global _CLAMP_WARNED_STATE
    if routed_shards is not None:
        p = int(routed_shards)
        if not 1 <= p <= num_shards:
            raise ValueError(
                f"routed_shards={routed_shards} must be in [1, "
                f"num_shards={num_shards}]: each query searches its top-p "
                f"shards by centroid distance")
        if p > n_live:
            # Warn once per ShardHealth state transition, not per call: a
            # degraded serving loop re-enters here every batch with the
            # same (num_shards, n_live, p) state.
            clamp_state = (num_shards, n_live, p)
            if _CLAMP_WARNED_STATE != clamp_state:
                warnings.warn(
                    f"routed_shards={p} exceeds the {n_live} live shards "
                    f"(shard_mask kills {num_shards - n_live}); clamping "
                    f"to {n_live} — every live shard is searched "
                    f"(DESIGN.md §14)",
                    stacklevel=2)
                _CLAMP_WARNED_STATE = clamp_state
            p = n_live
        else:
            _CLAMP_WARNED_STATE = None
        if p == num_shards:
            routed_shards = None       # degenerate: exact scatter-gather
        elif sharded_graph.centroids is None:
            raise ValueError(
                "routed_shards needs per-shard centroids; this ShardedGraph "
                "has none — rebuild it with graph.partition (any "
                "assignment), which stores them")
        else:
            routed_shards = p
    if tombstone_ids is not None:
        tombstone_ids = jnp.asarray(tombstone_ids, jnp.int32)
        if tombstone_ids.ndim != 1:
            raise ValueError(
                f"tombstone_ids must be a 1-D id array, got shape "
                f"{tombstone_ids.shape}")
        if tombstone_ids.shape[0] == 0:
            tombstone_ids = None       # empty: healthy cached program
    tomb = () if tombstone_ids is None else (tombstone_ids,)
    qargs = (() if quantize == "none" else
             (sharded_graph.qcodes, sharded_graph.qscale,
              sharded_graph.qnorms))
    b = queries.shape[0]
    if mesh is None:
        # default to the mesh the graph was placed on (graph.place_sharded
        # commits the arrays along "shard" at build/restore time), so the
        # jit'd program consumes the resident layout with no per-call
        # reshard; an explicit mesh must match that placement (jax raises
        # otherwise)
        mesh = sharding_lib.placement_mesh(sharded_graph.ids, num_shards)
    max_hops = max_hops or default_max_hops(ef, expand_width)
    dummy_d, dummy_has = fresh_cache(b, 1, False)
    live = jnp.asarray(np.ones(num_shards, bool) if shard_mask is None
                       else shard_mask)
    if routed_shards is None:
        run = _sharded_search_fn(
            mesh, k=k, ef=ef, max_hops=max_hops, metric=metric,
            visited_impl=visited_impl, hash_slots=hash_slots,
            expand_width=expand_width, tombstones=bool(tomb),
            quantize=bool(qargs))
        pool_i, pool_d, n_fresh, n_comp, hops = run(
            sharded_graph.ids, sharded_graph.data, sharded_graph.global_ids,
            sharded_graph.entries, live, queries,
            jnp.ones((b,), bool) if row_mask is None else row_mask,
            *qargs, *tomb)
        return SearchResult(pool_i, pool_d, n_fresh, n_comp, hops,
                            dummy_d, dummy_has)

    p = int(routed_shards)
    if mesh.size < num_shards and sharded_graph.flat_ids is not None:
        # Packed slots (> 1 shard per device): the shard_map body's
        # per-shard slices would materialize O(n/S) copies per shard per
        # call, so dispatch the fused single-search program over the
        # precomputed block-diagonal flat graph instead (DESIGN.md §13).
        # Bit-identical per routed (query, shard) row — pinned by test.
        run = _fused_routed_search_fn(
            k=k, ef=ef, max_hops=max_hops, metric=metric,
            visited_impl=visited_impl, hash_slots=hash_slots,
            expand_width=expand_width, p=p, tombstones=bool(tomb),
            quantize=bool(qargs))
        pool_i, pool_d, n_fresh, n_comp, hops = run(
            sharded_graph.flat_ids, sharded_graph.data,
            sharded_graph.global_ids, sharded_graph.entries,
            sharded_graph.centroids, live, queries,
            jnp.ones((b,), bool) if row_mask is None else row_mask,
            *qargs, *tomb)
        return SearchResult(pool_i, pool_d, n_fresh, n_comp, hops,
                            dummy_d, dummy_has)

    met = metric_lib.resolve(metric)
    qprep = met.prepare(queries)
    scores = metric_lib.kernel_distance(
        qprep[:, None, :], sharded_graph.centroids[None, :, :], met.kernel)
    scores = np.asarray(scores)
    if shard_mask is not None:
        # Host-side analogue of the fused path's in-jit masking: dead
        # shards score +inf and p <= n_live, so they are never routed to —
        # their blocks stay empty and contribute nothing to the psums.
        scores = np.where(shard_mask[None, :], scores, np.inf)
    routed = np.asarray(route_topk(jnp.asarray(scores), p))    # (b, p) asc
    rmask = (np.ones(b, bool) if row_mask is None
             else np.asarray(row_mask))
    # Compact per shard: shard s searches exactly the queries routed to it,
    # in query order; slot_of[b, j] is query b's row inside shard
    # routed[b, j]'s block.  Static bucketed block height (graph.bucket)
    # keeps the compiled-shape set small across batches.
    per_shard: list = [[] for _ in range(num_shards)]
    slot_of = np.zeros((b, p), np.int32)
    for i in range(b):
        if not rmask[i]:
            continue                     # padding queries route nowhere
        for j, s in enumerate(routed[i]):
            slot_of[i, j] = len(per_shard[s])
            per_shard[s].append(i)
    bq = graph_lib.bucket(max(1, max(len(l) for l in per_shard)),
                          ROUTED_BLOCK_MULT)
    q_index = np.zeros((num_shards, bq), np.int32)
    q_mask = np.zeros((num_shards, bq), bool)
    for s, rows in enumerate(per_shard):
        q_index[s, :len(rows)] = rows
        q_mask[s, :len(rows)] = True
    run = _routed_search_fn(
        mesh, k=k, ef=ef, max_hops=max_hops, metric=metric,
        visited_impl=visited_impl, hash_slots=hash_slots,
        expand_width=expand_width, p=p, tombstones=bool(tomb),
        quantize=bool(qargs))
    pool_i, pool_d, n_fresh, n_comp, hops = run(
        sharded_graph.ids, sharded_graph.data, sharded_graph.global_ids,
        sharded_graph.entries, queries, jnp.asarray(q_index),
        jnp.asarray(q_mask), jnp.asarray(routed), jnp.asarray(slot_of),
        jnp.asarray(rmask), *qargs, *tomb)
    return SearchResult(pool_i, pool_d, n_fresh, n_comp, hops,
                        dummy_d, dummy_has)
