"""BuildMultiHNSW — Algorithm 5, batched TPU adaptation.

m HNSW graphs with parameters {(efc_i, M_i)} share deterministic level draws
(one PRNG, DESIGN.md §3), so all m graphs have identical layer membership and
the same entry point.  Nodes are inserted in descending-level order in
batches; each batch descends the layer hierarchy with ef=1 searches, then
searches/prunes/commits on every layer it belongs to.  One V_delta per
inserted node is shared across *all* m graphs and *all* layers (Alg. 5 l.7).

Storage: ids int32[n_layers, m, n, M_max] — dense per layer (laptop-scale
simplicity; upper layers hold ~n/M rows).  alpha = 1 everywhere (HNSW).

``build_impl`` (DESIGN.md §12): "fused" replaces each layer's
search + mPrune + commit triple with one ``core/build.insert_batch``
dispatch (the greedy ef=1 descent between layers stays a plain search
dispatch); "per_batch" keeps the host-driven stages.  Both accumulate
counters on device (CounterTape) and sync once at the end of the build.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core import build as build_lib
from repro.core import commit, graph, hashset, prune, search
from repro.core import metric as metric_lib
from repro.core.counters import BuildCounters, CounterTape
from repro.core.graph import INVALID


@dataclasses.dataclass(frozen=True)
class HNSWParams:
    efc: int    # construction pool size
    M: int      # out-degree limit

    def clamped(self, n: int) -> "HNSWParams":
        return HNSWParams(min(self.efc, n - 1), min(self.M, n - 1))


@dataclasses.dataclass
class HNSWGraphs:
    layer_ids: jnp.ndarray    # int32[n_layers, m, n, M_max]
    layer_dist: jnp.ndarray   # float32[n_layers, m, n, M_max]
    levels: np.ndarray        # int32[n] shared deterministic levels
    entry: int                # global entry point (max-level node)
    top: int                  # top layer index


@dataclasses.dataclass
class HNSWBuildResult:
    g: HNSWGraphs
    counters: BuildCounters
    params: list
    metric: str = "l2"          # metric the graphs were built (and rank) under


def _mk_entry(b: int, m: int, ep: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.int32(ep), (b, m))


def build_multi_hnsw(
    data,
    params: list[HNSWParams],
    *,
    seed: int = 0,
    batch_size: int = 128,
    use_eso: bool = True,
    use_epo: bool = True,
    k_in: int = 16,
    max_level: int = 4,
    max_hops: int | None = None,
    metric: str = "l2",
    visited_impl: str = "dense",
    expand_width: int = 1,
    build_impl: str = "per_batch",
) -> HNSWBuildResult:
    build_impl = build_lib.resolve_build_impl(build_impl)
    met = metric_lib.resolve(metric)
    data = met.prepare(data)      # normalize ONCE for cosine (no-op otherwise)
    kform = met.kernel
    n, _ = data.shape
    params = [p.clamped(n) for p in params]
    m = len(params)
    efc = jnp.array([p.efc for p in params], jnp.int32)
    M = jnp.array([p.M for p in params], jnp.int32)
    ones = jnp.ones((m,), jnp.int32)
    alpha1 = jnp.ones((m,), jnp.float32)
    efc_max = graph.bucket(max(p.efc for p in params), 16)
    M_max = graph.bucket(max(p.M for p in params), 8)
    ctr = BuildCounters()
    tape = CounterTape()
    hops = max_hops or search.default_max_hops(efc_max)
    step_kw = dict(ef_max=efc_max, max_hops=hops, share_cache=use_eso,
                   use_epo=use_epo, metric=kform,
                   visited_impl=visited_impl,
                   expand_width=expand_width, k_in=k_in, m_max=M_max)

    # Deterministic shared levels; mL = 1/ln(M_ref) with M_ref = max_i M_i.
    m_l = 1.0 / math.log(max(2, M_max))
    levels = np.asarray(graph.hnsw_levels(seed, n, m_l, max_level))
    top = int(levels.max())
    order = np.lexsort((np.arange(n), -levels))     # descending level
    ep = int(order[0])
    n_layers = top + 1

    lids = jnp.full((n_layers, m, n, M_max), INVALID, jnp.int32)
    ldist = jnp.full((n_layers, m, n, M_max), jnp.inf, jnp.float32)

    # Geometric bootstrap: the first nodes would otherwise search a nearly
    # empty graph and stay isolated (GPU-HNSW-standard warmup schedule).
    offsets, off, step = [], 0, 8
    while off < n:
        offsets.append((off, min(step, batch_size)))
        off += min(step, batch_size)
        step *= 2

    for off, bsz in offsets:
        ids_np = order[off:off + bsz].astype(np.int32)
        b = batch_size  # static shape — bootstrap varies row_mask only
        u = jnp.full((b,), n, jnp.int32).at[:len(ids_np)].set(jnp.array(ids_np))
        row_mask_np = np.arange(b) < len(ids_np)
        lvl_np = np.zeros((b,), np.int32)
        lvl_np[:len(ids_np)] = levels[ids_np]
        queries = data[jnp.minimum(u, n - 1)]
        qids = jnp.where(jnp.array(row_mask_np), u, INVALID)
        entry = _mk_entry(b, m, ep)
        # One V_delta per inserted node across all layers/graphs; the hash
        # table must therefore cover m graphs x n_layers carried searches,
        # or inserts drop (and #dist inflates) before the size cap binds
        # (DESIGN.md §9).
        cache_d, cache_has = search.fresh_cache(
            b, n, use_eso, visited_impl,
            slots=hashset.auto_slots(hops, expand_width * M_max,
                                     searches=m * n_layers,
                                     cap=hashset.CACHE_SLOTS_CAP))

        for layer in range(top, -1, -1):
            desc_np = row_mask_np & (lvl_np < layer)
            ins_np = row_mask_np & (lvl_np >= layer)
            next_entry = entry
            if desc_np.any():   # greedy descent, Alg. 5 l.10-11
                res = search.beam_search(
                    lids[layer], data, queries, qids, jnp.array(desc_np),
                    ones, entry, cache_d, cache_has,
                    ef_max=1, max_hops=hops, share_cache=use_eso,
                    metric=kform, visited_impl=visited_impl)
                cache_d, cache_has = res.cache_d, res.cache_has
                tape.log(res.n_fresh, res.n_computed, 0, 0)
                got = res.pool_ids[:, :, 0]
                next_entry = jnp.where(
                    jnp.array(desc_np)[:, None] & (got != INVALID),
                    got, next_entry)
            if ins_np.any():    # search + mPrune + commit, Alg. 5 l.13-19
                ins_mask = jnp.array(ins_np)
                if build_impl == "fused":
                    # ONE dispatch for search + mPrune + commit of this
                    # layer's inserts (DESIGN.md §12).
                    nl, nd, row, got, cache_d, cache_has = (
                        build_lib.insert_batch(
                            lids[layer], ldist[layer], data, u, ins_mask,
                            queries, efc, M, alpha1, entry,
                            cache_d, cache_has, **step_kw))
                    tape.log_row(row)
                else:
                    res = search.beam_search(
                        lids[layer], data, queries, qids, ins_mask,
                        efc, entry, cache_d, cache_has,
                        ef_max=efc_max, max_hops=hops, share_cache=use_eso,
                        metric=kform, visited_impl=visited_impl,
                        expand_width=expand_width)
                    cache_d, cache_has = res.cache_d, res.cache_has
                    got = res.pool_ids[:, :, 0]

                    cand_ids = jnp.transpose(res.pool_ids, (1, 0, 2))
                    cand_dist = jnp.transpose(res.pool_dist, (1, 0, 2))
                    valid = cand_ids != INVALID
                    pruned, nb, nc = prune.multi_prune(
                        data, cand_ids, cand_dist, valid, M, alpha1,
                        m_max=M_max, use_epo=use_epo, metric=kform)
                    nl, nd, rev_checks = commit.commit_group(
                        data, lids[layer], ldist[layer], u, pruned,
                        ins_mask, M, alpha1, k_in=k_in, m_max=M_max,
                        metric=kform)
                    tape.log(res.n_fresh, res.n_computed,
                             nb + rev_checks, nc + rev_checks)
                next_entry = jnp.where(
                    ins_mask[:, None] & (got != INVALID), got, next_entry)
                lids = lids.at[layer].set(nl)
                ldist = ldist.at[layer].set(nd)
            entry = next_entry

    tape.drain_into(ctr)          # the build's ONE counter host sync
    g = HNSWGraphs(layer_ids=lids, layer_dist=ldist, levels=levels,
                   entry=ep, top=top)
    return HNSWBuildResult(g=g, counters=ctr, params=params, metric=met.name)


def build_hnsw(data, p: HNSWParams, **kw) -> HNSWBuildResult:
    kw.setdefault("use_eso", False)
    kw.setdefault("use_epo", False)
    return build_multi_hnsw(data, [p], **kw)


def hnsw_search(g: HNSWGraphs, graph_idx: int, data, queries, k: int, ef: int,
                max_hops: int | None = None, *,
                metric: str = "l2",
                visited_impl: str = "dense",
                expand_width: int = 1) -> search.SearchResult:
    """Layered k-ANNS on one of the m built HNSW graphs.

    ``expand_width`` applies to the base-layer beam search (DESIGN.md §10);
    the upper-layer greedy descent is ef=1 and always single-expansion.
    """
    if k > ef:
        raise ValueError(
            f"k={k} > ef={ef}: slots beyond ef are INVALID padding; raise "
            f"ef to at least k")
    met = metric_lib.resolve(metric)
    data = met.prepare(data)          # once, not per layer
    queries = met.prepare(queries)
    metric = met.kernel
    b = queries.shape[0]
    qids = jnp.full((b,), INVALID, jnp.int32)
    row = jnp.ones((b,), bool)
    entry = _mk_entry(b, 1, g.entry)
    hops = max_hops or search.default_max_hops(ef, expand_width)
    nf = nc = 0
    for layer in range(g.top, 0, -1):
        res = search.beam_search(
            g.layer_ids[layer, graph_idx][None], data, queries, qids, row,
            jnp.ones((1,), jnp.int32), entry,
            ef_max=1, max_hops=hops, share_cache=False, metric=metric,
            visited_impl=visited_impl)
        got = res.pool_ids[:, :, 0]
        entry = jnp.where(got != INVALID, got, entry)
        nf += int(res.n_fresh); nc += int(res.n_computed)
    res = search.beam_search(
        g.layer_ids[0, graph_idx][None], data, queries, qids, row,
        jnp.array([ef], jnp.int32), entry,
        ef_max=ef, max_hops=hops, share_cache=False, metric=metric,
        visited_impl=visited_impl, expand_width=expand_width)
    return search.SearchResult(
        res.pool_ids[:, 0, :k], res.pool_dist[:, 0, :k],
        res.n_fresh + nf, res.n_computed + nc, res.hops,
        res.cache_d, res.cache_has)
