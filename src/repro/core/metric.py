"""First-class distance metrics: L2, inner product, cosine.

Every layer of the stack (kernels -> search -> builders -> tuner -> serving)
ranks vectors by a *distance* (smaller = closer).  This module fixes the
similarity->distance convention once:

  l2      d(q, x) = ||q - x||^2                       (squared L2, >= 0)
  ip      d(q, x) = 1 - <q, x>                        (hnswlib convention)
  cosine  d(q, x) = 1 - cos(q, x) = 1 - <q~, x~>      (in [0, 2])

Monotone-transform notes:
  * ``1 - s`` is strictly decreasing in the similarity ``s``, so argmin over
    ip/cosine distance is exactly argmax over inner product / cosine
    similarity — rankings, top-k sets and Recall@k are unaffected by the
    affine shift (the +1 only keeps cosine distances non-negative, which the
    alpha-pruning rule ``alpha * d(v, w) < d(u, v)`` relies on: scaling by
    ``alpha >= 1`` must weaken, not strengthen, domination).
  * cosine reduces to ip on unit-normalized vectors, so the fused matmul
    kernel serves both; normalization happens ONCE at the data boundary
    (``Metric.prepare``), never inside the kernels or the search loop.
  * raw ip distance can be negative (unbounded similarity); pools pad with
    +inf so merges need no special-casing.

Only two *kernel forms* exist ("l2" and "ip"); ``Metric.kernel`` names the
form and ``Metric.prepare`` performs any required pre-transform.  Builders
resolve their metric once, prepare the dataset once, and thread the kernel
form through search/prune/commit — so the hot loops never re-normalize.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

KERNEL_FORMS = ("l2", "ip")


def normalize(x: jax.Array, *, eps: float = 1e-12) -> jax.Array:
    """Unit-normalize along the last axis (zero vectors stay zero-safe)."""
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def kernel_distance(a: jax.Array, b: jax.Array, kernel: str) -> jax.Array:
    """Distance along the last axis with broadcasting, per kernel form.

    Host-side elementwise helper shared by medoid / edge-distance / oracle
    sites; the pairwise hot paths (Pallas kernels, matmul-formulated
    references) keep their own MXU/BLAS-shaped implementations of the same
    convention.
    """
    if kernel == "ip":
        return 1.0 - jnp.sum(a * b, axis=-1)
    diff = a - b
    return jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)


@dataclasses.dataclass(frozen=True)
class Metric:
    """A distance metric the whole stack understands.

    Attributes:
      name:      public name ("l2" | "ip" | "cosine" | registered custom).
      kernel:    fused-kernel form computing the distance ("l2" or "ip").
      normalize: vectors must be unit-normalized before kernel calls;
                 ``prepare`` applies it (idempotent on unit vectors).
    """
    name: str
    kernel: str
    normalize: bool = False

    def __post_init__(self):
        if self.kernel not in KERNEL_FORMS:
            raise ValueError(
                f"kernel form {self.kernel!r} not in {KERNEL_FORMS}")

    def prepare(self, x: jax.Array) -> jax.Array:
        """One-time data-boundary transform (unit-normalize for cosine)."""
        return normalize(x) if self.normalize else x


L2 = Metric("l2", "l2")
IP = Metric("ip", "ip")
COSINE = Metric("cosine", "ip", normalize=True)

_REGISTRY: dict[str, Metric] = {m.name: m for m in (L2, IP, COSINE)}


def register(metric: Metric) -> Metric:
    """Add a custom metric to the registry (e.g. a scaled ip variant)."""
    _REGISTRY[metric.name] = metric
    return metric


def names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve(metric: "str | Metric") -> Metric:
    """Accept a Metric or its registered name; reject anything else."""
    if isinstance(metric, Metric):
        return metric
    try:
        return _REGISTRY[metric]
    except KeyError:
        raise ValueError(
            f"unknown metric {metric!r}; known: {sorted(_REGISTRY)}"
        ) from None
