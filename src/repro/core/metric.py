"""First-class distance metrics: L2, inner product, cosine.

Every layer of the stack (kernels -> search -> builders -> tuner -> serving)
ranks vectors by a *distance* (smaller = closer).  This module fixes the
similarity->distance convention once:

  l2      d(q, x) = ||q - x||^2                       (squared L2, >= 0)
  ip      d(q, x) = 1 - <q, x>                        (hnswlib convention)
  cosine  d(q, x) = 1 - cos(q, x) = 1 - <q~, x~>      (in [0, 2])

Monotone-transform notes:
  * ``1 - s`` is strictly decreasing in the similarity ``s``, so argmin over
    ip/cosine distance is exactly argmax over inner product / cosine
    similarity — rankings, top-k sets and Recall@k are unaffected by the
    affine shift (the +1 only keeps cosine distances non-negative, which the
    alpha-pruning rule ``alpha * d(v, w) < d(u, v)`` relies on: scaling by
    ``alpha >= 1`` must weaken, not strengthen, domination).
  * cosine reduces to ip on unit-normalized vectors, so the fused matmul
    kernel serves both; normalization happens ONCE at the data boundary
    (``Metric.prepare``), never inside the kernels or the search loop.
  * raw ip distance can be negative (unbounded similarity); pools pad with
    +inf so merges need no special-casing.

Only two *kernel forms* exist ("l2" and "ip"); ``Metric.kernel`` names the
form and ``Metric.prepare`` performs any required pre-transform.  Builders
resolve their metric once, prepare the dataset once, and thread the kernel
form through search/prune/commit — so the hot loops never re-normalize.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

KERNEL_FORMS = ("l2", "ip")

# Serving-time corpus representations (DESIGN.md §16): "none" is the fp32
# default (bit-identical to before the knob existed); "sq8" searches a
# scalar-quantized int8 corpus and re-ranks the final pool against fp32.
QUANTIZE_MODES = ("none", "sq8")


class QuantizedData(NamedTuple):
    """A scalar-quantized (symmetric per-dimension int8) corpus view.

    Produced ONCE at the data boundary (``Metric.prepare_quantized`` /
    ``quantize_sq8``) and consumed by the quantized kernel forms; a pytree,
    so it passes through jit boundaries and its *structure* keys the jit
    cache — the fp32 path (a bare array) dispatches its unchanged program.

    Attributes:
      codes: int8[n, d] — ``clip(round(x / scale), ±127)``.  The 4× memory
             win over the fp32 corpus.
      scale: f32[d] — per-dimension symmetric scale ``max|x[:, d]| / 127``
             (zero-point is identically 0; all-zero dimensions get scale 1
             so the division is always defined).
      norms: f32[n] — squared L2 norms of the DEQUANTIZED rows
             ``codes * scale``, precomputed so the l2 kernel form's norm
             expansion prices distances to the dequantized corpus exactly.
    """
    codes: jax.Array
    scale: jax.Array
    norms: jax.Array


def quantize_sq8(x: jax.Array) -> QuantizedData:
    """Symmetric per-dimension int8 scalar quantization (DESIGN.md §16).

    ``x`` must already be in prepared (kernel-form) space — cosine callers
    normalize first (``Metric.prepare_quantized`` does both).  Asymmetric
    compute (ADC): queries stay fp32 and are pre-scaled by ``scale`` once,
    so the cross term ``(q·scale)·codes ≡ q·(codes·scale)`` prices exact
    fp32 distances to the dequantized corpus — per-dimension scales cannot
    ride a pure int8×int8 dot.
    """
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=0)                       # (d,)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = codes.astype(jnp.float32) * scale
    norms = jnp.sum(deq * deq, axis=-1)
    return QuantizedData(codes=codes, scale=scale, norms=norms)


def normalize(x: jax.Array, *, eps: float = 1e-12) -> jax.Array:
    """Unit-normalize along the last axis (zero vectors stay zero-safe)."""
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def kernel_distance(a: jax.Array, b: jax.Array, kernel: str) -> jax.Array:
    """Distance along the last axis with broadcasting, per kernel form.

    Host-side elementwise helper shared by medoid / edge-distance / oracle
    sites; the pairwise hot paths (Pallas kernels, matmul-formulated
    references) keep their own MXU/BLAS-shaped implementations of the same
    convention.
    """
    if kernel == "ip":
        return 1.0 - jnp.sum(a * b, axis=-1)
    diff = a - b
    return jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)


@dataclasses.dataclass(frozen=True)
class Metric:
    """A distance metric the whole stack understands.

    Attributes:
      name:      public name ("l2" | "ip" | "cosine" | registered custom).
      kernel:    fused-kernel form computing the distance ("l2" or "ip").
      normalize: vectors must be unit-normalized before kernel calls;
                 ``prepare`` applies it (idempotent on unit vectors).
    """
    name: str
    kernel: str
    normalize: bool = False

    def __post_init__(self):
        if self.kernel not in KERNEL_FORMS:
            raise ValueError(
                f"kernel form {self.kernel!r} not in {KERNEL_FORMS}")

    def prepare(self, x: jax.Array) -> jax.Array:
        """One-time data-boundary transform (unit-normalize for cosine)."""
        return normalize(x) if self.normalize else x

    def prepare_quantized(self, x: jax.Array) -> QuantizedData:
        """Quantized data-boundary transform (DESIGN.md §16): ``prepare``
        (so cosine quantizes unit vectors), then symmetric int8 SQ.  Run
        once at ``build_index`` time; the scale/zero-point live on the
        index and its snapshot manifest, never recomputed at search time."""
        return quantize_sq8(self.prepare(x))


L2 = Metric("l2", "l2")
IP = Metric("ip", "ip")
COSINE = Metric("cosine", "ip", normalize=True)

_REGISTRY: dict[str, Metric] = {m.name: m for m in (L2, IP, COSINE)}


def register(metric: Metric) -> Metric:
    """Add a custom metric to the registry (e.g. a scaled ip variant)."""
    _REGISTRY[metric.name] = metric
    return metric


def names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve(metric: "str | Metric") -> Metric:
    """Accept a Metric or its registered name; reject anything else."""
    if isinstance(metric, Metric):
        return metric
    try:
        return _REGISTRY[metric]
    except KeyError:
        raise ValueError(
            f"unknown metric {metric!r}; known: {sorted(_REGISTRY)}"
        ) from None
