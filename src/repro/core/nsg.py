"""Multi-NSG construction — Alg. 6 variant per §IV-F of the paper.

Differences from Vamana: the initial graph is a *real* KNNG (exact blocked
brute force here instead of KGraph/nn-descent — a strictly-higher-quality
deterministic stand-in, DESIGN.md §8), searches run on that static KNNG
(not on the evolving graph), alpha is fixed at 1, and a connectivity-repair
pass re-attaches nodes unreachable from the medoid (NSG's spanning step).

Parameters per graph: (K_i initial out-degree, L_i pool, M_i degree limit).
The exact KNNG is computed once at K_max and every graph takes a prefix —
the deterministic shared-initialization strategy (counted once under ESO).

``build_impl`` (DESIGN.md §12): "fused" collapses each batch's
search + KNNG-row candidate merge + mPrune + commit into one
``core/build.nsg_insert_batch`` dispatch; "per_batch" keeps the
host-driven stages.  Both accumulate counters on device (CounterTape)
and sync once at the end of the main pass.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import build as build_lib
from repro.core import commit, graph, knng, prune, search
from repro.core import metric as metric_lib
from repro.core.counters import BuildCounters, CounterTape
from repro.core.graph import INVALID, MultiGraph
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class NSGParams:
    K: int      # initial KNNG out-degree
    L: int      # search pool size
    M: int      # out-degree limit

    def clamped(self, n: int) -> "NSGParams":
        return NSGParams(min(self.K, n - 1), min(self.L, n - 1),
                         min(self.M, n - 1))


@dataclasses.dataclass
class NSGBuildResult:
    g: MultiGraph
    entry: int
    counters: BuildCounters
    params: list
    metric: str = "l2"          # metric the graph was built (and ranks) under


def build_multi_nsg(
    data,
    params: list[NSGParams],
    *,
    seed: int = 0,           # unused (exact init is deterministic); kept for API
    batch_size: int = 128,
    use_eso: bool = True,
    use_epo: bool = True,
    k_in: int = 16,
    max_hops: int | None = None,
    repair_iters: int = 2,
    metric: str = "l2",
    visited_impl: str = "dense",
    expand_width: int = 1,
    build_impl: str = "per_batch",
) -> NSGBuildResult:
    build_impl = build_lib.resolve_build_impl(build_impl)
    del seed
    met = metric_lib.resolve(metric)
    data = met.prepare(data)      # normalize ONCE for cosine (no-op otherwise)
    kform = met.kernel
    n, _ = data.shape
    params = [p.clamped(n) for p in params]
    m = len(params)
    L = jnp.array([p.L for p in params], jnp.int32)
    M = jnp.array([p.M for p in params], jnp.int32)
    alpha1 = jnp.ones((m,), jnp.float32)
    L_max = graph.bucket(max(p.L for p in params), 16)
    M_max = graph.bucket(max(p.M for p in params), 8)
    K_max = graph.bucket(max(p.K for p in params), 8)
    ctr = BuildCounters()
    tape = CounterTape()
    hops = max_hops or search.default_max_hops(L_max)
    K = jnp.array([p.K for p in params], jnp.int32)

    # ---- Initialization: shared exact KNNG at K_max, per-graph prefixes ----
    knn_ids, knn_dist = knng.build_knng(data, K_max, metric=kform)
    init_knng = []
    for p in params:
        dm = jnp.arange(K_max)[None, :] < p.K
        init_knng.append(jnp.where(dm, knn_ids, INVALID))
    init_stack = jnp.stack(init_knng)                     # (m, n, K_max)
    ctr.init_base += m * knng.knng_dist_count(n)
    ctr.init += knng.knng_dist_count(n) if use_eso else ctr.init_base

    ep = int(graph.medoid(data, kform))
    g = graph.empty_multigraph(m, n, M_max)

    # ---- Search on the static KNNG + prune + commit (batched) --------------
    for off in range(0, n, batch_size):
        ids_np = np.arange(off, min(off + batch_size, n), dtype=np.int32)
        b = batch_size
        u = jnp.full((b,), n, jnp.int32).at[:len(ids_np)].set(
            jnp.array(ids_np))
        row_mask = jnp.arange(b) < len(ids_np)
        queries = data[jnp.minimum(u, n - 1)]
        entry = jnp.broadcast_to(jnp.int32(ep), (b, m))

        if build_impl == "fused":
            # ONE dispatch: search + KNNG-row merge + mPrune + commit
            # (DESIGN.md §12).  Same statements as below, traced.
            new_ids, new_dist, row = build_lib.nsg_insert_batch(
                init_stack, g.ids, g.dist, knn_ids, knn_dist, data, u,
                row_mask, queries, L, M, alpha1, K, entry,
                ef_max=L_max, max_hops=hops, share_cache=use_eso,
                use_epo=use_epo, metric=kform, visited_impl=visited_impl,
                expand_width=expand_width, k_in=k_in, m_max=M_max,
                k_max=K_max)
            g = MultiGraph(ids=new_ids, dist=new_dist)
            tape.log_row(row)
            continue

        res = search.beam_search(
            init_stack, data, queries, jnp.where(row_mask, u, INVALID),
            row_mask, L, entry, ef_max=L_max, max_hops=hops,
            share_cache=use_eso, metric=kform, visited_impl=visited_impl,
            expand_width=expand_width)

        # NSG's prune candidates are the nodes *visited* during search; the
        # pool alone loses u's local KNNG structure.  Merge each node's own
        # KNNG row (exact distances already known — no extra #dist) with the
        # search pool, dedup, and sort ascending (prune-order requirement).
        u_safe = jnp.minimum(u, n - 1)
        own_ids = jnp.broadcast_to(knn_ids[u_safe][None],
                                   (m,) + knn_ids[u_safe].shape)
        own_dist = jnp.broadcast_to(knn_dist[u_safe][None], own_ids.shape)
        kmask = (jnp.arange(K_max)[None, None, :] < K[:, None, None])
        own_ids = jnp.where(kmask & row_mask[None, :, None], own_ids, INVALID)
        own_dist = jnp.where(own_ids != INVALID, own_dist, jnp.inf)
        cand_ids = jnp.concatenate(
            [jnp.transpose(res.pool_ids, (1, 0, 2)), own_ids], axis=-1)
        cand_dist = jnp.concatenate(
            [jnp.transpose(res.pool_dist, (1, 0, 2)), own_dist], axis=-1)
        # dedup ids (keep first occurrence after sort-by-distance)
        srt = jnp.argsort(cand_dist, axis=-1)
        cand_ids = jnp.take_along_axis(cand_ids, srt, axis=-1)
        cand_dist = jnp.take_along_axis(cand_dist, srt, axis=-1)
        eq = cand_ids[:, :, None, :] == cand_ids[:, :, :, None]
        c = cand_ids.shape[-1]
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        dup = jnp.any(eq & tri[None, None], axis=-1)
        cand_ids = jnp.where(dup, INVALID, cand_ids)
        cand_dist = jnp.where(dup, jnp.inf, cand_dist)
        valid = cand_ids != INVALID
        pruned, nb, nc = prune.multi_prune(
            data, cand_ids, cand_dist, valid, M, alpha1,
            m_max=M_max, use_epo=use_epo, metric=kform)

        new_ids, new_dist, rev_checks = commit.commit_group(
            data, g.ids, g.dist, u, pruned, row_mask, M, alpha1,
            k_in=k_in, m_max=M_max, metric=kform)
        g = MultiGraph(ids=new_ids, dist=new_dist)
        tape.log(res.n_fresh, res.n_computed,
                 nb + rev_checks, nc + rev_checks)

    tape.drain_into(ctr)          # the main pass's ONE counter host sync

    # ---- connectivity repair (NSG spanning step, simplified) ---------------
    for _ in range(repair_iters):
        g, n_fix, n_dist = _repair_connectivity(g, data, ep, kform)
        ctr.connect += n_dist
        if n_fix == 0:
            break

    return NSGBuildResult(g=g, entry=ep, counters=ctr, params=params,
                          metric=met.name)


def _bfs_python(ids_i, reach, iters):
    """bool[n] BFS reachability via boolean frontier propagation."""
    n = ids_i.shape[0]
    for _ in range(iters):
        nbr = jnp.where(reach[:, None], jnp.maximum(ids_i, 0), n)
        nbr = jnp.where(
            reach[:, None] & (ids_i != INVALID), nbr, n)
        new = jnp.zeros((n,), bool).at[nbr.reshape(-1)].set(True, mode="drop")
        nxt = reach | new
        if bool(jnp.all(nxt == reach)):
            return nxt, False
        reach = nxt
    return reach, True


def _repair_connectivity(g: MultiGraph, data, ep: int, metric: str = "l2"
                         ) -> tuple[MultiGraph, int, int]:
    """Attach each unreachable node to its nearest reachable node."""
    m, n, M_max = g.ids.shape
    new_ids, new_dist = g.ids, g.dist
    total_fix = 0
    n_dist = 0
    for i in range(m):
        reach, _ = _bfs_python(g.ids[i], jnp.zeros((n,), bool).at[ep].set(True), 64)
        unreach = np.asarray(~reach).nonzero()[0]
        total_fix += len(unreach)
        if len(unreach) == 0:
            continue
        # nearest *reachable* node of each unreachable node (brute force on
        # the unreachable set — small in practice).
        q = data[jnp.array(unreach)]
        d2 = ops.pairwise_distance(q, data, metric)       # (u, n)
        d2 = jnp.where(reach[None, :], d2, jnp.inf)
        parent = jnp.argmin(d2, axis=-1).astype(jnp.int32)
        pdist = jnp.min(d2, axis=-1)
        n_dist += len(unreach) * n
        # parent -> unreachable edge: replace parent's worst slot.
        worst = jnp.argmax(new_dist[i][parent], axis=-1)
        new_ids = new_ids.at[i, parent, worst].set(jnp.array(unreach, jnp.int32))
        new_dist = new_dist.at[i, parent, worst].set(pdist)
    return MultiGraph(ids=new_ids, dist=new_dist), total_fix, n_dist


def build_nsg(data, p: NSGParams, **kw) -> NSGBuildResult:
    kw.setdefault("use_eso", False)
    kw.setdefault("use_epo", False)
    return build_multi_nsg(data, [p], **kw)
