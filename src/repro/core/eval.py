"""k-ANNS evaluation: Recall@k, QPS, and the tuning objective.

Parameter *estimation* in the paper = build the PG, then measure the
(QPS, Recall@k) frontier over the query set.  ``ef`` is a search-time knob
tuned jointly with the construction parameters (VDTuner's setup): an
evaluation sweeps ef values and reports the best QPS meeting each recall
target plus the raw (QPS, Recall) points for the MOBO tuner.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knng, search
from repro.core.graph import INVALID, MultiGraph


@dataclasses.dataclass
class EvalPoint:
    ef: int
    recall: float
    qps: float
    n_dist: int


def recall_at_k(found_ids: jax.Array, gt_ids: jax.Array) -> float:
    """Mean |found ∩ gt| / |valid gt| over the query batch.

    Both sides may be INVALID-padded (short pools, dead shards, n < k
    ground truth).  An INVALID ground-truth slot is *padding*, not a
    neighbor: matching it against an INVALID found slot must not count
    as a hit, so matches are masked to valid gt entries and each query
    normalizes by its own valid-gt count (floored at 1 — an all-padding
    gt row contributes 0, not NaN).
    """
    valid = gt_ids != INVALID
    match = (found_ids[:, :, None] == gt_ids[:, None, :]) & valid[:, None, :]
    hits = match.any(-1)
    denom = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    return float(jnp.mean(jnp.sum(hits, axis=-1) / denom))


def ground_truth(data, queries, k: int, metric: str = "l2") -> jax.Array:
    """Metric-correct exact top-k ids (recall denominators must match the
    metric the index ranks by, or cross-metric frontiers aren't comparable)."""
    ids, _ = knng.exact_knn(data, queries, k, metric=metric)
    return ids


def evaluate_search_fn(
    search_fn: Callable[[jax.Array, int], search.SearchResult],
    queries: jax.Array,
    gt_ids: jax.Array,
    k: int,
    ef_grid: list[int],
    *,
    timing_reps: int = 2,
) -> list[EvalPoint]:
    """Sweep ef, returning (recall, QPS) per point.

    ``search_fn(queries, ef)`` must return a SearchResult whose pool prefix
    holds k ids.  QPS is measured wall-clock (CPU here; DESIGN.md §8 —
    *ratios* across configurations are the reproduced quantity).
    """
    points = []
    nq = queries.shape[0]
    for ef in ef_grid:
        res = search_fn(queries, ef)
        jax.block_until_ready(res.pool_ids)
        t0 = time.perf_counter()
        for _ in range(timing_reps):
            r2 = search_fn(queries, ef)
            jax.block_until_ready(r2.pool_ids)
        dt = (time.perf_counter() - t0) / timing_reps
        rec = recall_at_k(res.pool_ids[:, :k], gt_ids)
        points.append(EvalPoint(ef=ef, recall=rec, qps=nq / max(dt, 1e-9),
                                n_dist=int(res.n_computed)))
    return points


def flat_graph_search_fn(g: MultiGraph, graph_idx: int, data, entry: int,
                         k: int, metric: str = "l2",
                         visited_impl: str = "dense",
                         expand_width: int = 1):
    """Search closure for single-layer graphs (Vamana/NSG)."""
    def fn(queries, ef):
        return search.knn_search(
            g.ids[graph_idx], data, queries, k, ef, entry, metric=metric,
            visited_impl=visited_impl, expand_width=expand_width)
    return fn


def best_qps_at_recall(points: list[EvalPoint], target: float) -> float:
    """Best QPS among eval points meeting Recall@k >= target (0 if none)."""
    ok = [p.qps for p in points if p.recall >= target]
    return max(ok) if ok else 0.0


def frontier_objectives(points: list[EvalPoint]) -> tuple[float, float]:
    """(best QPS, best recall) summary pair used as the MOBO observation."""
    if not points:
        return 0.0, 0.0
    # VDTuner observes one (QPS, Recall) per configuration; we follow its
    # protocol of reporting the knee point: maximize qps * recall.
    best = max(points, key=lambda p: p.qps * max(p.recall, 1e-6))
    return best.qps, best.recall


def pareto_points(points: list[EvalPoint]) -> list[EvalPoint]:
    """Non-dominated subset of the (QPS, recall) sweep."""
    out = []
    for p in points:
        if not any((q.qps >= p.qps and q.recall >= p.recall and
                    (q.qps > p.qps or q.recall > p.recall)) for q in points):
            out.append(p)
    return sorted(out, key=lambda p: p.recall)
