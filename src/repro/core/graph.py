"""Proximity-graph representation and shared utilities.

TPU-native representation: a PG over ``n`` vectors is a dense adjacency
matrix ``int32[n, M_max]`` padded with ``INVALID = -1``; ``m`` simultaneously
constructed graphs stack to ``int32[m, n, M_max]``.  Distances annotate edges
as ``float32`` with ``+inf`` padding so top-k merges need no branching.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib

INVALID = -1
INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MultiGraph:
    """m stacked PGs over the same vertex set.

    Attributes:
      ids:  int32[m, n, M_max]  out-neighbor ids, INVALID-padded.
      dist: float32[m, n, M_max] matching edge lengths, +inf-padded.
    """
    ids: jax.Array
    dist: jax.Array

    @property
    def m(self) -> int:
        return self.ids.shape[0]

    @property
    def n(self) -> int:
        return self.ids.shape[1]

    @property
    def max_degree(self) -> int:
        return self.ids.shape[2]


def empty_multigraph(m: int, n: int, max_degree: int) -> MultiGraph:
    return MultiGraph(
        ids=jnp.full((m, n, max_degree), INVALID, jnp.int32),
        dist=jnp.full((m, n, max_degree), INF, jnp.float32),
    )


def degree(g: MultiGraph) -> jax.Array:
    """int32[m, n] current out-degrees."""
    return jnp.sum(g.ids != INVALID, axis=-1).astype(jnp.int32)


def medoid(data: jax.Array, metric: str = "l2") -> jax.Array:
    """Index of the vector closest (under ``metric``) to the dataset centroid."""
    met = metric_lib.resolve(metric)
    data = met.prepare(data)
    c = jnp.mean(data, axis=0, keepdims=True)
    d = metric_lib.kernel_distance(data, c, met.kernel)
    return jnp.argmin(d).astype(jnp.int32)


def sort_edges(ids: jax.Array, dist: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort edge lists ascending by distance (axis -1), INVALID/+inf last."""
    order = jnp.argsort(dist, axis=-1)
    return (jnp.take_along_axis(ids, order, axis=-1),
            jnp.take_along_axis(dist, order, axis=-1))


# ---------------------------------------------------------------------------
# Deterministic random strategy (FastPGT §IV-C).
#
# All randomness used during construction is a pure function of
# (seed, node_id), so the m simultaneously built graphs see *identical* HNSW
# level draws and *identical* initial-KNNG neighbor prefixes, maximizing the
# structural overlap the ESO/EPO sharing exploits.  Nothing is stored: values
# are regenerated on demand (O(1) memory, as in the paper).
# ---------------------------------------------------------------------------

def hnsw_levels(seed: int, n: int, m_l: float, max_level: int) -> jax.Array:
    """Deterministic HNSW level per node: floor(-ln U * m_l), clipped."""
    key = jax.random.PRNGKey(seed)
    u = jax.random.uniform(key, (n,), jnp.float32, minval=1e-9, maxval=1.0)
    lvl = jnp.floor(-jnp.log(u) * m_l).astype(jnp.int32)
    return jnp.clip(lvl, 0, max_level)


def random_knng_ids(seed: int, n: int, degree: int) -> jax.Array:
    """Deterministic random initial KNNG ids int32[n, degree].

    Row u is a prefix-stable pseudo-random sequence: a graph needing a
    smaller initial degree takes a prefix of the same row, so all m Vamana
    initial graphs overlap maximally (deterministic random strategy).
    Self-loops are redirected to (u+1) mod n.
    """
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    ids = jax.random.randint(key, (n, degree), 0, n, jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    return jnp.where(ids == rows, (ids + 1) % n, ids)


def with_distances(data: jax.Array, ids: jax.Array,
                   metric: str = "l2") -> jax.Array:
    """Edge distances float32[..., k] for id matrix int32[n, k] (INVALID->inf)."""
    met = metric_lib.resolve(metric)
    data = met.prepare(data)
    src = data[jnp.arange(ids.shape[0])[:, None]]          # (n, 1, d) via bcast
    dst = data[jnp.clip(ids, 0, None)]                     # (n, k, d)
    d2 = metric_lib.kernel_distance(dst, src, met.kernel)
    return jnp.where(ids == INVALID, INF, d2).astype(jnp.float32)


def stack_graphs(gs: list[tuple[jax.Array, jax.Array]],
                 max_degree: int) -> MultiGraph:
    """Stack per-graph (ids, dist) with per-graph degrees into a MultiGraph."""
    ids, dist = [], []
    for gid, gdist in gs:
        pad = max_degree - gid.shape[-1]
        ids.append(jnp.pad(gid, ((0, 0), (0, pad)), constant_values=INVALID))
        dist.append(jnp.pad(gdist, ((0, 0), (0, pad)), constant_values=INF))
    return MultiGraph(ids=jnp.stack(ids), dist=jnp.stack(dist))


def degree_mask(m: int, max_degree: int, degrees: jax.Array) -> jax.Array:
    """bool[m, max_degree]: slot j active for graph i iff j < degrees[i]."""
    return jnp.arange(max_degree)[None, :] < degrees[:, None]


def bucket(x: int, mult: int) -> int:
    """Round up to a multiple — static-shape bucketing for compile reuse."""
    return -(-x // mult) * mult


def pytree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))
