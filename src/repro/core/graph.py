"""Proximity-graph representation and shared utilities.

TPU-native representation: a PG over ``n`` vectors is a dense adjacency
matrix ``int32[n, M_max]`` padded with ``INVALID = -1``; ``m`` simultaneously
constructed graphs stack to ``int32[m, n, M_max]``.  Distances annotate edges
as ``float32`` with ``+inf`` padding so top-k merges need no branching.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib

INVALID = -1
INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MultiGraph:
    """m stacked PGs over the same vertex set.

    Attributes:
      ids:  int32[m, n, M_max]  out-neighbor ids, INVALID-padded.
      dist: float32[m, n, M_max] matching edge lengths, +inf-padded.
    """
    ids: jax.Array
    dist: jax.Array

    @property
    def m(self) -> int:
        return self.ids.shape[0]

    @property
    def n(self) -> int:
        return self.ids.shape[1]

    @property
    def max_degree(self) -> int:
        return self.ids.shape[2]


def empty_multigraph(m: int, n: int, max_degree: int) -> MultiGraph:
    return MultiGraph(
        ids=jnp.full((m, n, max_degree), INVALID, jnp.int32),
        dist=jnp.full((m, n, max_degree), INF, jnp.float32),
    )


def degree(g: MultiGraph) -> jax.Array:
    """int32[m, n] current out-degrees."""
    return jnp.sum(g.ids != INVALID, axis=-1).astype(jnp.int32)


def medoid(data: jax.Array, metric: str = "l2") -> jax.Array:
    """Index of the vector closest (under ``metric``) to the dataset centroid."""
    met = metric_lib.resolve(metric)
    data = met.prepare(data)
    c = jnp.mean(data, axis=0, keepdims=True)
    d = metric_lib.kernel_distance(data, c, met.kernel)
    return jnp.argmin(d).astype(jnp.int32)


def sort_edges(ids: jax.Array, dist: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort edge lists ascending by distance (axis -1), INVALID/+inf last."""
    order = jnp.argsort(dist, axis=-1)
    return (jnp.take_along_axis(ids, order, axis=-1),
            jnp.take_along_axis(dist, order, axis=-1))


# ---------------------------------------------------------------------------
# Deterministic random strategy (FastPGT §IV-C).
#
# All randomness used during construction is a pure function of
# (seed, node_id), so the m simultaneously built graphs see *identical* HNSW
# level draws and *identical* initial-KNNG neighbor prefixes, maximizing the
# structural overlap the ESO/EPO sharing exploits.  Nothing is stored: values
# are regenerated on demand (O(1) memory, as in the paper).
# ---------------------------------------------------------------------------

def hnsw_levels(seed: int, n: int, m_l: float, max_level: int) -> jax.Array:
    """Deterministic HNSW level per node: floor(-ln U * m_l), clipped."""
    key = jax.random.PRNGKey(seed)
    u = jax.random.uniform(key, (n,), jnp.float32, minval=1e-9, maxval=1.0)
    lvl = jnp.floor(-jnp.log(u) * m_l).astype(jnp.int32)
    return jnp.clip(lvl, 0, max_level)


def random_knng_ids(seed: int, n: int, degree: int) -> jax.Array:
    """Deterministic random initial KNNG ids int32[n, degree].

    Row u is a prefix-stable pseudo-random sequence: a graph needing a
    smaller initial degree takes a prefix of the same row, so all m Vamana
    initial graphs overlap maximally (deterministic random strategy).
    Self-loops are redirected to (u+1) mod n.
    """
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    ids = jax.random.randint(key, (n, degree), 0, n, jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    return jnp.where(ids == rows, (ids + 1) % n, ids)


def with_distances(data: jax.Array, ids: jax.Array,
                   metric: str = "l2") -> jax.Array:
    """Edge distances float32[..., k] for id matrix int32[n, k] (INVALID->inf)."""
    met = metric_lib.resolve(metric)
    data = met.prepare(data)
    src = data[jnp.arange(ids.shape[0])[:, None]]          # (n, 1, d) via bcast
    dst = data[jnp.clip(ids, 0, None)]                     # (n, k, d)
    d2 = metric_lib.kernel_distance(dst, src, met.kernel)
    return jnp.where(ids == INVALID, INF, d2).astype(jnp.float32)


def stack_graphs(gs: list[tuple[jax.Array, jax.Array]],
                 max_degree: int) -> MultiGraph:
    """Stack per-graph (ids, dist) with per-graph degrees into a MultiGraph."""
    ids, dist = [], []
    for gid, gdist in gs:
        pad = max_degree - gid.shape[-1]
        ids.append(jnp.pad(gid, ((0, 0), (0, pad)), constant_values=INVALID))
        dist.append(jnp.pad(gdist, ((0, 0), (0, pad)), constant_values=INF))
    return MultiGraph(ids=jnp.stack(ids), dist=jnp.stack(dist))


def degree_mask(m: int, max_degree: int, degrees: jax.Array) -> jax.Array:
    """bool[m, max_degree]: slot j active for graph i iff j < degrees[i]."""
    return jnp.arange(max_degree)[None, :] < degrees[:, None]


def bucket(x: int, mult: int) -> int:
    """Round up to a multiple — static-shape bucketing for compile reuse."""
    return -(-x // mult) * mult


# ---------------------------------------------------------------------------
# Corpus sharding (DESIGN.md §11) and query routing (DESIGN.md §13).
#
# Scatter-gather partitioned search splits the corpus into ``num_shards``
# disjoint node sets; each shard holds its own vectors and a subgraph over
# them in *shard-local* int32 ids, plus the local -> global id map used to
# restore global ids when per-shard pools merge.  Shards are padded to a
# common row count so they stack on a leading axis that a "shard" mesh axis
# can partition (core/search.py sharded_knn_search) — the first place the
# corpus-resident arrays stop being replicated across devices.
#
# Every partition also records a per-shard *centroid* (the mean of the
# shard's metric-prepared member vectors): the routing statistic
# ``search.sharded_knn_search(routed_shards=p)`` scores queries against to
# search only the p most promising shards (DESIGN.md §13).  The "kmeans"
# assignment optimizes exactly that statistic — mini-batch k-means with
# jitted Lloyd steps, balanced by capacity-constrained rounding — while
# "chunked"/"random" keep their placement and merely report their means.
# ---------------------------------------------------------------------------

ASSIGNMENTS = ("chunked", "random", "kmeans")

# mini-batch k-means schedule (Sculley-style per-centroid learning rates);
# build-time only, so the defaults favor determinism and partition quality
KMEANS_BATCH = 4096
KMEANS_EPOCHS = 8
# capacity slack ε: shards may hold up to ⌈n/S · (1+ε)⌉ rows.  A hard
# ⌈n/S⌉ cap forcibly spills cluster-boundary points into geometrically
# wrong shards, and each misplaced point is a routing recall hole (its
# neighborhood stays behind); 5% slack removes most forced spills while
# keeping shards balanced enough for the mesh (DESIGN.md §13).
KMEANS_CAP_SLACK = 0.05


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedGraph:
    """num_shards stacked per-shard subindexes over a partitioned corpus.

    Attributes:
      ids:        int32[S, n_s, Mx] shard-local out-neighbor ids
                  (INVALID-padded; they index rows of the same shard).
      data:       float32[S, n_s, d] shard-local vectors (padding rows are
                  zero and unreachable: no adjacency row points at them).
      global_ids: int32[S, n_s] local row -> global id (INVALID on padding).
      entries:    int32[S] shard-local search entry point per shard.
      counts:     int32[S] real (non-padding) rows per shard.
      centroids:  float32[S, d] routing statistic in metric-prepared space
                  — ``search.sharded_knn_search(routed_shards=p)`` scores
                  queries against it (DESIGN.md §13).  Lloyd centroids for
                  kmeans partitions (the statistic the placement
                  optimized), member means otherwise.  None on
                  ShardedGraphs constructed before routing existed
                  (routing then raises).
      flat_ids:   int32[S * n_s, Mx] the same per-shard adjacency in
                  *stacked-flat* id space (shard s row i lives at
                  s * n_s + i; INVALID padding preserved).  The graph is
                  block-diagonal — no row points outside its shard — so a
                  single beam search over it explores exactly one shard
                  per query row.  Precomputed here because the fused
                  routed path (DESIGN.md §13) would otherwise pay an
                  O(n·Mx) offset materialization per search call; None on
                  pre-routing ShardedGraphs (the routed search then falls
                  back to the shard_map path).
      qcodes:     int8[S, n_s, d] SQ8 codes of the metric-prepared shard
                  rows (DESIGN.md §16), present iff the graph was
                  quantized (``quantize_sharded``); None otherwise
                  (``sharded_knn_search(quantize="sq8")`` then raises).
      qscale:     float32[S, d] per-dimension SQ scale — one GLOBAL scale
                  replicated per shard row so codes are comparable across
                  shards and the fused routed path can use any row.
      qnorms:     float32[S, n_s] squared norms of the dequantized rows.
    """
    ids: jax.Array
    data: jax.Array
    global_ids: jax.Array
    entries: jax.Array
    counts: jax.Array
    centroids: jax.Array | None = None
    flat_ids: jax.Array | None = None
    qcodes: jax.Array | None = None
    qscale: jax.Array | None = None
    qnorms: jax.Array | None = None

    @property
    def num_shards(self) -> int:
        return self.ids.shape[0]

    @property
    def shard_rows(self) -> int:
        return self.ids.shape[1]

    @property
    def max_degree(self) -> int:
        return self.ids.shape[2]


@functools.partial(
    jax.jit, static_argnames=("num_shards", "kernel", "batch", "epochs"))
def _kmeans_fit(x: jax.Array, key: jax.Array, *, num_shards: int,
                kernel: str, batch: int, epochs: int) -> jax.Array:
    """Mini-batch k-means centroids float32[S, d], one compiled dispatch.

    Sculley-style: each Lloyd step assigns one mini-batch to its nearest
    centroid under ``kernel`` distance and moves centroids by the
    count-weighted running mean (per-centroid learning rate 1/seen_count),
    so early batches move centroids fast and late batches anneal.  Each
    epoch re-shuffles via ``fold_in(key, epoch)``; the ragged tail of a
    shuffle is dropped to keep every batch the same static shape.  Pure
    function of (x, key) — partition determinism inherits from here.
    """
    n = x.shape[0]
    cents = x[jax.random.choice(key, n, (num_shards,), replace=False)]
    counts = jnp.ones((num_shards,), jnp.float32)
    nb = max(n // batch, 1)

    def batch_step(carry, ids):
        cents, counts = carry
        xb = x[ids]                                              # (batch, d)
        d = metric_lib.kernel_distance(xb[:, None, :], cents[None, :, :],
                                       kernel)                   # (batch, S)
        a = jnp.argmin(d, axis=-1)
        cnt = jax.ops.segment_sum(jnp.ones_like(a, jnp.float32), a,
                                  num_segments=num_shards)
        sx = jax.ops.segment_sum(xb, a, num_segments=num_shards)  # (S, d)
        counts = counts + cnt
        cents = cents + (sx - cnt[:, None] * cents) / counts[:, None]
        return (cents, counts), None

    def epoch(e, carry):
        perm = jax.random.permutation(jax.random.fold_in(key, e), n)
        carry, _ = jax.lax.scan(batch_step, carry,
                                perm[:nb * batch].reshape(nb, batch))
        return carry

    cents, _ = jax.lax.fori_loop(0, epochs, epoch, (cents, counts))
    return cents


def _capacity_round(dist, cap: int):
    """Round a soft k-means assignment to a ≤ ``cap``-per-shard hard one.

    Deterministic host-side spill rounds over ``dist`` float[n, S]:
    every point starts at its argmin column; while some shard exceeds
    ``cap``, that shard keeps its ``cap`` closest movable members (stable
    sort; forced members — rows with only one finite column left — always
    stay) and spills the rest, striking the spilled (row, col) entries to
    +inf so a point never bounces back.  Each productive round strikes
    ≥ 1 entry of the finite n×S budget, so the loop terminates.  Shards
    left empty (duplicate centroids can starve one) are repaired by
    moving the closest point under the ORIGINAL distances from a donor
    shard that keeps ≥ 1 member.  Returns int assignment[n].
    """
    import numpy as np
    n, num_shards = dist.shape
    orig = np.asarray(dist, np.float64)
    d = orig.copy()
    assign = np.argmin(d, axis=1)
    while True:
        counts = np.bincount(assign, minlength=num_shards)
        over = np.flatnonzero(counts > cap)
        if over.size == 0:
            break
        moved = False
        for s in over:
            members = np.flatnonzero(assign == s)
            if members.size <= cap:       # earlier spill this round shrank it
                continue
            movable = members[np.isfinite(d[members]).sum(axis=1) > 1]
            keep = max(cap - (members.size - movable.size), 0)
            order = np.argsort(d[movable, s], kind="stable")
            spill = movable[order[keep:]]
            if spill.size == 0:           # all forced: accept the overflow
                continue
            moved = True
            d[spill, s] = np.inf
            assign[spill] = np.argmin(d[spill], axis=1)
        if not moved:
            break
    counts = np.bincount(assign, minlength=num_shards)
    for s in np.flatnonzero(counts == 0):
        for i in np.argsort(orig[:, s], kind="stable"):
            if counts[assign[i]] > 1:
                counts[assign[i]] -= 1
                assign[i] = s
                counts[s] += 1
                break
    return assign


def _kmeans_parts(n: int, num_shards: int, data, metric: str, seed: int):
    """(per-shard global-id arrays, Lloyd centroids f32[S, d]) for "kmeans".

    The centroids returned are the CLUSTERING MODEL's, not the rounded
    members' means: capacity rounding spills boundary points, and scoring
    queries against post-spill member means ranks shards differently from
    the statistic the placement optimized — measured as routing recall
    holes on cluster boundaries (DESIGN.md §13).
    """
    import numpy as np
    met = metric_lib.resolve(metric)
    x = met.prepare(jnp.asarray(data, jnp.float32))
    cents = _kmeans_fit(
        x, jax.random.PRNGKey(seed ^ 0xC3A7), num_shards=num_shards,
        kernel=met.kernel, batch=min(KMEANS_BATCH, n), epochs=KMEANS_EPOCHS)
    d = metric_lib.kernel_distance(x[:, None, :], cents[None, :, :],
                                   met.kernel)
    cap = int(np.ceil(n / num_shards * (1.0 + KMEANS_CAP_SLACK)))
    assign = _capacity_round(np.asarray(d), cap)
    return [np.flatnonzero(assign == s).astype(np.int32)
            for s in range(num_shards)], cents


def shard_assignment(n: int, num_shards: int, *, assignment: str = "chunked",
                     seed: int = 0, data: jax.Array | None = None,
                     metric: str = "l2") -> list:
    """Global-id arrays per shard (ascending within each shard).

    "chunked" splits [0, n) into contiguous runs (np.array_split balance:
    the first n % S shards get one extra row); "random" deterministically
    permutes ids first (pure function of ``seed`` — the deterministic
    random strategy of §IV-C applied to placement), then chunks the
    permutation.  "kmeans" (DESIGN.md §13) clusters ``data`` (required)
    with mini-batch k-means under ``metric`` and balances the assignment
    by capacity-constrained rounding (cap = ⌈n/S · (1+ε)⌉,
    ε = KMEANS_CAP_SLACK), so routed searches can skip shards without
    any shard hoarding the corpus.  Every id lands in exactly one shard;
    every path is deterministic in ``seed``.
    """
    import numpy as np
    if assignment not in ASSIGNMENTS:
        raise ValueError(f"assignment {assignment!r} not in {ASSIGNMENTS}")
    if not 1 <= num_shards <= n:
        raise ValueError(
            f"num_shards={num_shards} must be in [1, n={n}]: an empty shard "
            f"has no entry point")
    if assignment == "kmeans":
        if data is None:
            raise ValueError(
                "assignment='kmeans' clusters the corpus vectors: pass "
                "data= (the other assignments are data-independent)")
        return _kmeans_parts(n, num_shards, data, metric, seed)[0]
    ids = np.arange(n, dtype=np.int32)
    if assignment == "random":
        ids = np.random.default_rng(seed).permutation(ids)
    return [np.sort(part) for part in np.array_split(ids, num_shards)]


def partition(data: jax.Array, num_shards: int, *,
              assignment: str = "chunked", seed: int = 0,
              graph_ids: jax.Array | None = None,
              build_fn=None, degree: int = 16,
              metric: str = "l2", quantize: str = "none",
              mesh=None) -> ShardedGraph:
    """Partition a corpus (and its graph) into a ``ShardedGraph``.

    Per-shard subgraphs come from one of three sources:
      * ``build_fn(local_data) -> (ids, entry)``: build a fresh subindex
        over each shard's vectors (what serving uses — per-shard Vamana,
        see serve/retrieval.py).  ``ids`` are shard-local.
      * ``graph_ids`` int32[n, Mx]: induce from an existing global graph —
        local rows keep only in-shard edges, remapped to local ids.
        Cross-shard edges are dropped (documented recall cost, DESIGN.md
        §11), so this path is for structure-preserving experiments, not
        quality-sensitive serving.
      * neither: exact KNNG of ``degree`` per shard (knng.build_knng) —
        the quality default at container scale.
    Entry points come from ``build_fn`` when given, else the shard-local
    medoid under ``metric``.  ``assignment`` picks the placement
    (shard_assignment; "kmeans" clusters ``data`` under ``metric``), and
    every mode stores per-shard centroids for query routing (DESIGN.md
    §13).

    The result is placed onto ``mesh`` (default: the ``"shard"`` mesh
    ``distributed.sharding.search_mesh(num_shards)``) with every array
    split along the shard axis — done ONCE here so repeated
    ``sharded_knn_search`` calls never re-scatter the corpus.  Note the
    capacity guarantee is for *steady-state search*: construction stages
    the full corpus (and the stacked per-shard arrays) on the default
    device before that one placement, so building truly
    beyond-device-memory indexes needs shard-at-a-time staging — the
    multi-host follow-up DESIGN.md §11 names.

    ``quantize="sq8"`` additionally stores SQ8 codes for every shard
    (``quantize_sharded``, DESIGN.md §16) so
    ``sharded_knn_search(quantize="sq8")`` can search int8; the graph is
    always built over the fp32 vectors either way (§2.1 bit-identity).
    """
    import numpy as np

    if quantize not in metric_lib.QUANTIZE_MODES:
        raise ValueError(
            f"quantize {quantize!r} not in {metric_lib.QUANTIZE_MODES}")

    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core import knng as knng_lib   # local: keeps graph.py light
    from repro.distributed import sharding as sharding_lib

    data = jnp.asarray(data)
    n = data.shape[0]
    # Routing statistic per assignment mode (DESIGN.md §13): kmeans shards
    # get the Lloyd centroid their placement optimized (NOT the post-
    # rounding member mean — see _kmeans_parts); chunked/random report
    # their member means (routing over them is legal, just geometrically
    # blind).
    if assignment == "kmeans":
        if not 1 <= num_shards <= n:     # mirror shard_assignment's guard
            raise ValueError(
                f"num_shards={num_shards} must be in [1, n={n}]: an empty "
                f"shard has no entry point")
        parts, cents = _kmeans_parts(n, num_shards, data, metric, seed)
    else:
        parts = shard_assignment(n, num_shards, assignment=assignment,
                                 seed=seed)
        prepared = metric_lib.resolve(metric).prepare(data)
        cents = jnp.stack([jnp.mean(prepared[jnp.asarray(part)], axis=0)
                           for part in parts])
    cents = jnp.asarray(cents, jnp.float32)
    all_ids, all_data, all_gids, entries = [], [], [], []
    for part in parts:
        c = len(part)
        local = data[jnp.asarray(part)]
        if build_fn is not None:
            lids, entry = build_fn(local)
            lids = jnp.asarray(lids, jnp.int32)
        elif graph_ids is not None:
            g = jnp.asarray(graph_ids)
            if g.ndim == 3:       # (1, n, Mx) MultiGraph slice
                g = g[0]
            rows = np.asarray(g)[part]                     # (c, Mx) global
            inv = np.full(n, INVALID, np.int32)
            inv[part] = np.arange(c, dtype=np.int32)
            lids = jnp.asarray(
                np.where(rows >= 0, inv[np.maximum(rows, 0)], INVALID))
            entry = int(medoid(local, metric))
        else:
            lids, _ = knng_lib.build_knng(local, min(degree, c - 1),
                                          metric=metric)
            entry = int(medoid(local, metric))
        all_ids.append(lids)
        all_data.append(local)
        all_gids.append(jnp.asarray(part, jnp.int32))
        entries.append(entry)
    sg = assemble_sharded(all_ids, all_data, all_gids, entries,
                          centroids=cents, mesh=mesh)
    if quantize == "sq8":
        sg = quantize_sharded(sg, metric=metric, mesh=mesh)
    return sg


def assemble_sharded(ids_parts, data_parts, gid_parts, entries, *,
                     centroids=None, mesh=None) -> ShardedGraph:
    """Pad/stack per-shard (local graph, vectors, global ids) into a placed
    ``ShardedGraph``.

    The shared assembly tail of ``partition`` — and the seam streaming
    compaction (serve/streaming.py, DESIGN.md §15) reuses to restack a mix
    of freshly rebuilt and untouched shards without re-partitioning: each
    shard contributes ragged (c_s, Mx_s) local adjacency, (c_s, d) vectors
    and (c_s,) global ids; padding, the stacked-flat adjacency for the
    fused routed path, and mesh placement all happen here exactly as at
    first build, so a compacted index dispatches the same cached search
    programs as a fresh one.
    """
    n_s = max(x.shape[0] for x in data_parts)
    mx = max(g.shape[-1] for g in ids_parts)
    counts = [int(x.shape[0]) for x in data_parts]
    ids = jnp.stack([
        jnp.pad(jnp.asarray(g, jnp.int32),
                ((0, n_s - g.shape[0]), (0, mx - g.shape[1])),
                constant_values=INVALID) for g in ids_parts])
    dat = jnp.stack([
        jnp.pad(jnp.asarray(x), ((0, n_s - x.shape[0]), (0, 0)))
        for x in data_parts])
    gids = jnp.stack([
        jnp.pad(jnp.asarray(g, jnp.int32), (0, n_s - g.shape[0]),
                constant_values=INVALID) for g in gid_parts])
    # Stacked-flat adjacency for the fused routed path (DESIGN.md §13):
    # offset each shard's local ids into the concatenated row space once at
    # build time (INVALID padding stays INVALID, so padded rows stay
    # unreachable and the flat graph stays block-diagonal).
    offs = (jnp.arange(len(counts), dtype=jnp.int32) * n_s)[:, None, None]
    flat = jnp.where(ids >= 0, ids + offs, INVALID).reshape(-1, mx)
    sg = ShardedGraph(ids=ids, data=dat, global_ids=gids,
                      entries=jnp.asarray(entries, jnp.int32),
                      counts=jnp.asarray(counts, jnp.int32),
                      centroids=(None if centroids is None
                                 else jnp.asarray(centroids, jnp.float32)),
                      flat_ids=flat)
    return place_sharded(sg, mesh=mesh)


def place_sharded(sg: ShardedGraph, mesh=None) -> ShardedGraph:
    """Commit a ShardedGraph's arrays onto the ``"shard"`` mesh.

    The one ``device_put`` of the sharded-search lifecycle — ``partition``
    calls it at build time, and ``serve.resilience.load_index`` calls it
    when restoring a snapshot, so a restored index gets the same resident
    layout (and hence the same zero-reshard dispatch) as a freshly built
    one.  Default mesh: ``distributed.sharding.search_mesh(num_shards)``.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.distributed import sharding as sharding_lib

    mesh = mesh or sharding_lib.search_mesh(sg.num_shards)
    return jax.device_put(sg, NamedSharding(mesh, PartitionSpec("shard")))


def quantize_sharded(sg: ShardedGraph, metric: str = "l2",
                     mesh=None) -> ShardedGraph:
    """Attach SQ8 codes to a ShardedGraph (DESIGN.md §16).

    Computes ONE global per-dimension scale over the metric-prepared
    corpus (padding rows are zero, so they never raise the abs-max — the
    scale equals the unpadded corpus's) and stores per-shard int8 codes
    plus dequantized-row norms, re-placed on the ``"shard"`` mesh.  The
    scale is replicated per shard row so every shard's codes decode with
    the same statistic and the fused routed path can read any row.
    """
    met = metric_lib.resolve(metric)
    num_shards, n_s, d = sg.data.shape
    q = quantize_sq8_data(sg.data.reshape(-1, d), met)
    sg = dataclasses.replace(
        sg,
        qcodes=q.codes.reshape(num_shards, n_s, d),
        qscale=jnp.tile(q.scale[None, :], (num_shards, 1)),
        qnorms=q.norms.reshape(num_shards, n_s))
    return place_sharded(sg, mesh=mesh)


def quantize_sq8_data(data: jax.Array, metric) -> metric_lib.QuantizedData:
    """``Metric.prepare_quantized`` with a convenient string/Metric arg."""
    return metric_lib.resolve(metric).prepare_quantized(data)


def pytree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))
