"""Proximity-graph representation and shared utilities.

TPU-native representation: a PG over ``n`` vectors is a dense adjacency
matrix ``int32[n, M_max]`` padded with ``INVALID = -1``; ``m`` simultaneously
constructed graphs stack to ``int32[m, n, M_max]``.  Distances annotate edges
as ``float32`` with ``+inf`` padding so top-k merges need no branching.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib

INVALID = -1
INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MultiGraph:
    """m stacked PGs over the same vertex set.

    Attributes:
      ids:  int32[m, n, M_max]  out-neighbor ids, INVALID-padded.
      dist: float32[m, n, M_max] matching edge lengths, +inf-padded.
    """
    ids: jax.Array
    dist: jax.Array

    @property
    def m(self) -> int:
        return self.ids.shape[0]

    @property
    def n(self) -> int:
        return self.ids.shape[1]

    @property
    def max_degree(self) -> int:
        return self.ids.shape[2]


def empty_multigraph(m: int, n: int, max_degree: int) -> MultiGraph:
    return MultiGraph(
        ids=jnp.full((m, n, max_degree), INVALID, jnp.int32),
        dist=jnp.full((m, n, max_degree), INF, jnp.float32),
    )


def degree(g: MultiGraph) -> jax.Array:
    """int32[m, n] current out-degrees."""
    return jnp.sum(g.ids != INVALID, axis=-1).astype(jnp.int32)


def medoid(data: jax.Array, metric: str = "l2") -> jax.Array:
    """Index of the vector closest (under ``metric``) to the dataset centroid."""
    met = metric_lib.resolve(metric)
    data = met.prepare(data)
    c = jnp.mean(data, axis=0, keepdims=True)
    d = metric_lib.kernel_distance(data, c, met.kernel)
    return jnp.argmin(d).astype(jnp.int32)


def sort_edges(ids: jax.Array, dist: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort edge lists ascending by distance (axis -1), INVALID/+inf last."""
    order = jnp.argsort(dist, axis=-1)
    return (jnp.take_along_axis(ids, order, axis=-1),
            jnp.take_along_axis(dist, order, axis=-1))


# ---------------------------------------------------------------------------
# Deterministic random strategy (FastPGT §IV-C).
#
# All randomness used during construction is a pure function of
# (seed, node_id), so the m simultaneously built graphs see *identical* HNSW
# level draws and *identical* initial-KNNG neighbor prefixes, maximizing the
# structural overlap the ESO/EPO sharing exploits.  Nothing is stored: values
# are regenerated on demand (O(1) memory, as in the paper).
# ---------------------------------------------------------------------------

def hnsw_levels(seed: int, n: int, m_l: float, max_level: int) -> jax.Array:
    """Deterministic HNSW level per node: floor(-ln U * m_l), clipped."""
    key = jax.random.PRNGKey(seed)
    u = jax.random.uniform(key, (n,), jnp.float32, minval=1e-9, maxval=1.0)
    lvl = jnp.floor(-jnp.log(u) * m_l).astype(jnp.int32)
    return jnp.clip(lvl, 0, max_level)


def random_knng_ids(seed: int, n: int, degree: int) -> jax.Array:
    """Deterministic random initial KNNG ids int32[n, degree].

    Row u is a prefix-stable pseudo-random sequence: a graph needing a
    smaller initial degree takes a prefix of the same row, so all m Vamana
    initial graphs overlap maximally (deterministic random strategy).
    Self-loops are redirected to (u+1) mod n.
    """
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    ids = jax.random.randint(key, (n, degree), 0, n, jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    return jnp.where(ids == rows, (ids + 1) % n, ids)


def with_distances(data: jax.Array, ids: jax.Array,
                   metric: str = "l2") -> jax.Array:
    """Edge distances float32[..., k] for id matrix int32[n, k] (INVALID->inf)."""
    met = metric_lib.resolve(metric)
    data = met.prepare(data)
    src = data[jnp.arange(ids.shape[0])[:, None]]          # (n, 1, d) via bcast
    dst = data[jnp.clip(ids, 0, None)]                     # (n, k, d)
    d2 = metric_lib.kernel_distance(dst, src, met.kernel)
    return jnp.where(ids == INVALID, INF, d2).astype(jnp.float32)


def stack_graphs(gs: list[tuple[jax.Array, jax.Array]],
                 max_degree: int) -> MultiGraph:
    """Stack per-graph (ids, dist) with per-graph degrees into a MultiGraph."""
    ids, dist = [], []
    for gid, gdist in gs:
        pad = max_degree - gid.shape[-1]
        ids.append(jnp.pad(gid, ((0, 0), (0, pad)), constant_values=INVALID))
        dist.append(jnp.pad(gdist, ((0, 0), (0, pad)), constant_values=INF))
    return MultiGraph(ids=jnp.stack(ids), dist=jnp.stack(dist))


def degree_mask(m: int, max_degree: int, degrees: jax.Array) -> jax.Array:
    """bool[m, max_degree]: slot j active for graph i iff j < degrees[i]."""
    return jnp.arange(max_degree)[None, :] < degrees[:, None]


def bucket(x: int, mult: int) -> int:
    """Round up to a multiple — static-shape bucketing for compile reuse."""
    return -(-x // mult) * mult


# ---------------------------------------------------------------------------
# Corpus sharding (DESIGN.md §11).
#
# Scatter-gather partitioned search splits the corpus into ``num_shards``
# disjoint node sets; each shard holds its own vectors and a subgraph over
# them in *shard-local* int32 ids, plus the local -> global id map used to
# restore global ids when per-shard pools merge.  Shards are padded to a
# common row count so they stack on a leading axis that a "shard" mesh axis
# can partition (core/search.py sharded_knn_search) — the first place the
# corpus-resident arrays stop being replicated across devices.
# ---------------------------------------------------------------------------

ASSIGNMENTS = ("chunked", "random")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedGraph:
    """num_shards stacked per-shard subindexes over a partitioned corpus.

    Attributes:
      ids:        int32[S, n_s, Mx] shard-local out-neighbor ids
                  (INVALID-padded; they index rows of the same shard).
      data:       float32[S, n_s, d] shard-local vectors (padding rows are
                  zero and unreachable: no adjacency row points at them).
      global_ids: int32[S, n_s] local row -> global id (INVALID on padding).
      entries:    int32[S] shard-local search entry point per shard.
      counts:     int32[S] real (non-padding) rows per shard.
    """
    ids: jax.Array
    data: jax.Array
    global_ids: jax.Array
    entries: jax.Array
    counts: jax.Array

    @property
    def num_shards(self) -> int:
        return self.ids.shape[0]

    @property
    def shard_rows(self) -> int:
        return self.ids.shape[1]

    @property
    def max_degree(self) -> int:
        return self.ids.shape[2]


def shard_assignment(n: int, num_shards: int, *, assignment: str = "chunked",
                     seed: int = 0) -> list:
    """Global-id arrays per shard (ascending within each shard).

    "chunked" splits [0, n) into contiguous runs (np.array_split balance:
    the first n % S shards get one extra row); "random" deterministically
    permutes ids first (pure function of ``seed`` — the deterministic
    random strategy of §IV-C applied to placement), then chunks the
    permutation.  Every id lands in exactly one shard.
    """
    import numpy as np
    if assignment not in ASSIGNMENTS:
        raise ValueError(f"assignment {assignment!r} not in {ASSIGNMENTS}")
    if not 1 <= num_shards <= n:
        raise ValueError(
            f"num_shards={num_shards} must be in [1, n={n}]: an empty shard "
            f"has no entry point")
    ids = np.arange(n, dtype=np.int32)
    if assignment == "random":
        ids = np.random.default_rng(seed).permutation(ids)
    return [np.sort(part) for part in np.array_split(ids, num_shards)]


def partition(data: jax.Array, num_shards: int, *,
              assignment: str = "chunked", seed: int = 0,
              graph_ids: jax.Array | None = None,
              build_fn=None, degree: int = 16,
              metric: str = "l2", mesh=None) -> ShardedGraph:
    """Partition a corpus (and its graph) into a ``ShardedGraph``.

    Per-shard subgraphs come from one of three sources:
      * ``build_fn(local_data) -> (ids, entry)``: build a fresh subindex
        over each shard's vectors (what serving uses — per-shard Vamana,
        see serve/retrieval.py).  ``ids`` are shard-local.
      * ``graph_ids`` int32[n, Mx]: induce from an existing global graph —
        local rows keep only in-shard edges, remapped to local ids.
        Cross-shard edges are dropped (documented recall cost, DESIGN.md
        §11), so this path is for structure-preserving experiments, not
        quality-sensitive serving.
      * neither: exact KNNG of ``degree`` per shard (knng.build_knng) —
        the quality default at container scale.
    Entry points come from ``build_fn`` when given, else the shard-local
    medoid under ``metric``.

    The result is placed onto ``mesh`` (default: the ``"shard"`` mesh
    ``distributed.sharding.search_mesh(num_shards)``) with every array
    split along the shard axis — done ONCE here so repeated
    ``sharded_knn_search`` calls never re-scatter the corpus.  Note the
    capacity guarantee is for *steady-state search*: construction stages
    the full corpus (and the stacked per-shard arrays) on the default
    device before that one placement, so building truly
    beyond-device-memory indexes needs shard-at-a-time staging — the
    multi-host follow-up DESIGN.md §11 names.
    """
    import numpy as np

    from jax.sharding import NamedSharding, PartitionSpec

    from repro.core import knng as knng_lib   # local: keeps graph.py light
    from repro.distributed import sharding as sharding_lib

    data = jnp.asarray(data)
    n = data.shape[0]
    parts = shard_assignment(n, num_shards, assignment=assignment, seed=seed)
    n_s = max(len(p) for p in parts)
    all_ids, all_data, all_gids, entries, counts = [], [], [], [], []
    mx = 0
    for part in parts:
        c = len(part)
        local = data[jnp.asarray(part)]
        if build_fn is not None:
            lids, entry = build_fn(local)
            lids = jnp.asarray(lids, jnp.int32)
        elif graph_ids is not None:
            g = jnp.asarray(graph_ids)
            if g.ndim == 3:       # (1, n, Mx) MultiGraph slice
                g = g[0]
            rows = np.asarray(g)[part]                     # (c, Mx) global
            inv = np.full(n, INVALID, np.int32)
            inv[part] = np.arange(c, dtype=np.int32)
            lids = jnp.asarray(
                np.where(rows >= 0, inv[np.maximum(rows, 0)], INVALID))
            entry = int(medoid(local, metric))
        else:
            lids, _ = knng_lib.build_knng(local, min(degree, c - 1),
                                          metric=metric)
            entry = int(medoid(local, metric))
        mx = max(mx, lids.shape[-1])
        all_ids.append(lids)
        all_data.append(local)
        all_gids.append(jnp.asarray(part, jnp.int32))
        entries.append(entry)
        counts.append(c)
    ids = jnp.stack([
        jnp.pad(g, ((0, n_s - g.shape[0]), (0, mx - g.shape[1])),
                constant_values=INVALID) for g in all_ids])
    dat = jnp.stack([
        jnp.pad(x, ((0, n_s - x.shape[0]), (0, 0))) for x in all_data])
    gids = jnp.stack([
        jnp.pad(g, (0, n_s - g.shape[0]), constant_values=INVALID)
        for g in all_gids])
    sg = ShardedGraph(ids=ids, data=dat, global_ids=gids,
                      entries=jnp.asarray(entries, jnp.int32),
                      counts=jnp.asarray(counts, jnp.int32))
    mesh = mesh or sharding_lib.search_mesh(num_shards)
    return jax.device_put(sg, NamedSharding(mesh, PartitionSpec("shard")))


def pytree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))
