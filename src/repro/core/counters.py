"""Distance-computation accounting — the paper's #dist metric.

Counts are *logical* distance computations per the paper's model (DESIGN.md
§3): ``*_base`` is what independent per-graph builds (no ESO/EPO) would
compute, the unsuffixed field is what the shared build actually computed.
Totals live in Python ints (unbounded); per-step counts are int32 device
scalars that builders log on a ``CounterTape`` and sync to the host ONCE at
the end of the build loop (DESIGN.md §12) — a per-batch ``int(...)`` cast
would block the host on every dispatched batch.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class BuildCounters:
    search_base: int = 0   # Search phase, independent builds
    search: int = 0        # Search phase, with ESO sharing
    prune_base: int = 0    # Prune dominance checks, independent builds
    prune: int = 0         # with EPO sharing
    init_base: int = 0     # Initialization (KNNG) distances, independent
    init: int = 0          # shared across the group
    connect: int = 0       # connectivity-repair searches (NSG)

    @property
    def total_base(self) -> int:
        return self.search_base + self.prune_base + self.init_base + self.connect

    @property
    def total(self) -> int:
        return self.search + self.prune + self.init + self.connect

    def add(self, other: "BuildCounters") -> "BuildCounters":
        return BuildCounters(
            self.search_base + other.search_base, self.search + other.search,
            self.prune_base + other.prune_base, self.prune + other.prune,
            self.init_base + other.init_base, self.init + other.init,
            self.connect + other.connect)

    def as_dict(self) -> dict:
        return {
            "search_base": self.search_base, "search": self.search,
            "prune_base": self.prune_base, "prune": self.prune,
            "init_base": self.init_base, "init": self.init,
            "connect": self.connect,
            "total_base": self.total_base, "total": self.total,
        }


# Per-step counter-row layout shared by the builders and the fused batch
# step (core/build.py): one int32[4] row per dispatched step.
TAPE_FIELDS = ("search_base", "search", "prune_base", "prune")


def step_row(n_fresh, n_computed, n_prune_base, n_prune) -> jnp.ndarray:
    """One CounterTape row (int32[4]) from a batch step's device scalars."""
    return jnp.stack([jnp.asarray(n_fresh, jnp.int32),
                      jnp.asarray(n_computed, jnp.int32),
                      jnp.asarray(n_prune_base, jnp.int32),
                      jnp.asarray(n_prune, jnp.int32)])


class CounterTape:
    """Device-side accumulation of per-step counter increments.

    Build loops used to close every batch with ``int(res.n_fresh)`` casts —
    four host round-trips per dispatched batch, each blocking the host until
    the device drained its queue, which serializes dispatch even on the
    per_batch path.  The tape instead *logs* each step's int32[4] increment
    row as a device array (async, never blocks) and syncs exactly once:
    ``drain_into`` stacks the rows, fetches them in one transfer, and sums
    on the host in int64 (no int32 overflow however long the build ran).

    The fused device-resident pass (core/build.py) writes its rows into a
    preallocated int32[n_steps, 4] log inside ``lax.fori_loop`` and hands
    the whole log to ``drain_into`` via ``log_many`` — same sync contract,
    zero per-batch dispatches.
    """

    def __init__(self):
        self._rows: list = []

    def log(self, n_fresh, n_computed, n_prune_base, n_prune) -> None:
        self._rows.append(step_row(n_fresh, n_computed,
                                   n_prune_base, n_prune))

    def log_row(self, row) -> None:
        """Log a prebuilt int32[4] row (e.g. a fused step's output)."""
        self._rows.append(row)

    def log_many(self, rows) -> None:
        """Log an int32[k, 4] block (a device-resident pass's whole log)."""
        self._rows.append(jnp.asarray(rows).reshape(-1, 4))

    def drain_into(self, ctr: BuildCounters) -> None:
        """ONE host sync: fetch every logged row, add totals into ``ctr``."""
        if not self._rows:
            return
        stacked = jnp.concatenate(
            [jnp.atleast_2d(r) for r in self._rows], axis=0)
        totals = np.asarray(stacked).astype(np.int64).sum(axis=0)
        self._rows = []
        for name, v in zip(TAPE_FIELDS, totals):
            setattr(ctr, name, getattr(ctr, name) + int(v))
