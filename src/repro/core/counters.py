"""Distance-computation accounting — the paper's #dist metric.

Counts are *logical* distance computations per the paper's model (DESIGN.md
§3): ``*_base`` is what independent per-graph builds (no ESO/EPO) would
compute, the unsuffixed field is what the shared build actually computed.
Accumulated in Python ints across jitted batch steps (per-step counts are
int32; totals here are unbounded).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class BuildCounters:
    search_base: int = 0   # Search phase, independent builds
    search: int = 0        # Search phase, with ESO sharing
    prune_base: int = 0    # Prune dominance checks, independent builds
    prune: int = 0         # with EPO sharing
    init_base: int = 0     # Initialization (KNNG) distances, independent
    init: int = 0          # shared across the group
    connect: int = 0       # connectivity-repair searches (NSG)

    @property
    def total_base(self) -> int:
        return self.search_base + self.prune_base + self.init_base + self.connect

    @property
    def total(self) -> int:
        return self.search + self.prune + self.init + self.connect

    def add(self, other: "BuildCounters") -> "BuildCounters":
        return BuildCounters(
            self.search_base + other.search_base, self.search + other.search,
            self.prune_base + other.prune_base, self.prune + other.prune,
            self.init_base + other.init_base, self.init + other.init,
            self.connect + other.connect)

    def as_dict(self) -> dict:
        return {
            "search_base": self.search_base, "search": self.search,
            "prune_base": self.prune_base, "prune": self.prune,
            "init_base": self.init_base, "init": self.init,
            "connect": self.connect,
            "total_base": self.total_base, "total": self.total,
        }
