"""BuildMultiVamana — Algorithm 6, batched TPU adaptation.

m Vamana graphs with parameters {(L_i, M_i, alpha_i)} are built in one pass
over the dataset (R = L per Theorem 1).  Each insertion batch searches the
graph frozen at batch start (standard GPU/TPU relaxation, DESIGN.md §3),
shares one V_delta across the m per-node searches (ESO), chains the m prunes
through mPrune (EPO, group sorted ascending by alpha for soundness), and
commits forward + reverse edges with overflow re-prune.  ``expand_width``
stays 1 by default so construction follows the paper's sequential
best-first schedule exactly (DESIGN.md §10).

``visited_impl`` selects the search's visit-state representation; builds
default to "dense" so graph outputs and #dist counters stay bit-identical
to the paper's accounting (DESIGN.md §2.1, §9) — "hash" trades exact
counters for O(ef)-memory search state.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import commit, graph, prune, search
from repro.core import metric as metric_lib
from repro.core.counters import BuildCounters
from repro.core.graph import INVALID, MultiGraph


@dataclasses.dataclass(frozen=True)
class VamanaParams:
    L: int          # search pool size (= R per Theorem 1)
    M: int          # out-degree limit
    alpha: float    # pruning parameter

    def clamped(self, n: int) -> "VamanaParams":
        return VamanaParams(min(self.L, n - 1), min(self.M, n - 1), self.alpha)


@dataclasses.dataclass
class BuildResult:
    g: MultiGraph               # in the *original* parameter order
    entry: int
    counters: BuildCounters
    params: list
    metric: str = "l2"          # metric the graph was built (and ranks) under


def build_multi_vamana(
    data,
    params: list[VamanaParams],
    *,
    seed: int = 0,
    batch_size: int = 128,
    use_eso: bool = True,
    use_epo: bool = True,
    k_in: int = 16,
    max_hops: int | None = None,
    metric: str = "l2",
    visited_impl: str = "dense",
    expand_width: int = 1,
) -> BuildResult:
    met = metric_lib.resolve(metric)
    data = met.prepare(data)      # normalize ONCE for cosine (no-op otherwise)
    kform = met.kernel            # hot loops see only the kernel form
    n, _ = data.shape
    params = [p.clamped(n) for p in params]
    m = len(params)
    order = sorted(range(m), key=lambda i: params[i].alpha)   # EPO soundness
    inv_order = np.argsort(order)
    ps = [params[i] for i in order]
    L = jnp.array([p.L for p in ps], jnp.int32)
    M = jnp.array([p.M for p in ps], jnp.int32)
    alpha = jnp.array([p.alpha for p in ps], jnp.float32)
    # Static shape maxima rounded to buckets: per-graph masks enforce the
    # true L_i/M_i, so padding never changes results — it keeps compiled
    # shapes identical across tuning iterations (one XLA compile, reused).
    L_max = graph.bucket(max(p.L for p in ps), 16)
    M_max = graph.bucket(max(p.M for p in ps), 8)
    ctr = BuildCounters()

    # ---- Initialization: deterministic shared random KNNG (Alg. 6 l.1-2) ---
    init_ids = graph.random_knng_ids(seed, n, M_max)          # shared prefix
    init_dist = graph.with_distances(data, init_ids, kform)
    gids, gdist = [], []
    for p in ps:
        dm = jnp.arange(M_max)[None, :] < p.M
        gids.append(jnp.where(dm, init_ids, INVALID))
        gdist.append(jnp.where(dm, init_dist, jnp.inf))
    g = MultiGraph(ids=jnp.stack(gids), dist=jnp.stack(gdist))
    ctr.init_base += sum(n * p.M for p in ps)
    ctr.init += n * M_max if use_eso else ctr.init_base

    ep = int(graph.medoid(data, kform))                       # Alg. 6 l.3
    hops = max_hops or search.default_max_hops(L_max)

    # ---- main pass (Alg. 6 l.4-12), batched ---------------------------------
    for off in range(0, n, batch_size):
        ids_np = np.arange(off, min(off + batch_size, n), dtype=np.int32)
        b = batch_size
        u = jnp.full((b,), n, jnp.int32).at[:len(ids_np)].set(ids_np)
        row_mask = jnp.arange(b) < len(ids_np)
        queries = data[jnp.minimum(u, n - 1)]
        entry = jnp.broadcast_to(jnp.int32(ep), (b, m))

        res = search.beam_search(
            g.ids, data, queries, jnp.where(row_mask, u, INVALID), row_mask,
            L, entry, ef_max=L_max, max_hops=hops, share_cache=use_eso,
            metric=kform, visited_impl=visited_impl,
            expand_width=expand_width)
        ctr.search_base += int(res.n_fresh)
        ctr.search += int(res.n_computed)

        cand_ids = jnp.transpose(res.pool_ids, (1, 0, 2))     # (m, b, L_max)
        cand_dist = jnp.transpose(res.pool_dist, (1, 0, 2))
        valid = cand_ids != INVALID
        pruned, nb, nc = prune.multi_prune(
            data, cand_ids, cand_dist, valid, M, alpha,
            m_max=M_max, use_epo=use_epo, metric=kform)
        ctr.prune_base += int(nb)
        ctr.prune += int(nc)

        new_ids, new_dist = commit.commit_group(
            data, g.ids, g.dist, u, pruned, row_mask, M, alpha, ctr,
            k_in=k_in, m_max=M_max, metric=kform)
        g = MultiGraph(ids=new_ids, dist=new_dist)

    g = MultiGraph(ids=g.ids[inv_order], dist=g.dist[inv_order])
    return BuildResult(g=g, entry=ep, counters=ctr, params=params,
                       metric=met.name)


def build_vamana(data, p: VamanaParams, **kw) -> BuildResult:
    """Single-graph build (baseline estimation path: no sharing possible)."""
    kw.setdefault("use_eso", False)
    kw.setdefault("use_epo", False)
    return build_multi_vamana(data, [p], **kw)
