"""BuildMultiVamana — Algorithm 6, batched TPU adaptation.

m Vamana graphs with parameters {(L_i, M_i, alpha_i)} are built in one pass
over the dataset (R = L per Theorem 1).  Each insertion batch searches the
graph frozen at batch start (standard GPU/TPU relaxation, DESIGN.md §3),
shares one V_delta across the m per-node searches (ESO), chains the m prunes
through mPrune (EPO, group sorted ascending by alpha for soundness), and
commits forward + reverse edges with overflow re-prune.  ``expand_width``
stays 1 by default so construction follows the paper's sequential
best-first schedule exactly (DESIGN.md §10).

``visited_impl`` selects the search's visit-state representation; builds
default to "dense" so graph outputs and #dist counters stay bit-identical
to the paper's accounting (DESIGN.md §2.1, §9) — "hash" trades exact
counters for O(ef)-memory search state.

``build_impl`` selects the batch-step execution strategy (DESIGN.md §12):
"per_batch" drives each batch from the host (one search dispatch + m prune
+ m commit dispatches per batch), "fused" runs the whole insertion pass as
ONE device-resident compiled dispatch (``core/build.fused_vamana_pass``).
Graphs and counters are bit-identical at test scale; at large n the fused
path deviates from the staged path at the ppm level because per_batch's
eager prune-stage distance reduction accumulates in a different order than
the compiled step (documented + bounded, DESIGN.md §12).  Both paths
accumulate counters on device and sync to the host once at the end.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import build as build_lib
from repro.core import commit, graph, prune, search
from repro.core import metric as metric_lib
from repro.core.counters import BuildCounters, CounterTape
from repro.core.graph import INVALID, MultiGraph


@dataclasses.dataclass(frozen=True)
class VamanaParams:
    L: int          # search pool size (= R per Theorem 1)
    M: int          # out-degree limit
    alpha: float    # pruning parameter

    def clamped(self, n: int) -> "VamanaParams":
        return VamanaParams(min(self.L, n - 1), min(self.M, n - 1), self.alpha)


@dataclasses.dataclass
class BuildResult:
    g: MultiGraph               # in the *original* parameter order
    entry: int
    counters: BuildCounters
    params: list
    metric: str = "l2"          # metric the graph was built (and ranks) under


def build_multi_vamana(
    data,
    params: list[VamanaParams],
    *,
    seed: int = 0,
    batch_size: int = 128,
    use_eso: bool = True,
    use_epo: bool = True,
    k_in: int = 16,
    max_hops: int | None = None,
    metric: str = "l2",
    visited_impl: str = "dense",
    expand_width: int = 1,
    build_impl: str = "per_batch",
) -> BuildResult:
    build_impl = build_lib.resolve_build_impl(build_impl)
    met = metric_lib.resolve(metric)
    data = met.prepare(data)      # normalize ONCE for cosine (no-op otherwise)
    kform = met.kernel            # hot loops see only the kernel form
    n, _ = data.shape
    params = [p.clamped(n) for p in params]
    m = len(params)
    order = sorted(range(m), key=lambda i: params[i].alpha)   # EPO soundness
    inv_order = np.argsort(order)
    ps = [params[i] for i in order]
    L = jnp.array([p.L for p in ps], jnp.int32)
    M = jnp.array([p.M for p in ps], jnp.int32)
    alpha = jnp.array([p.alpha for p in ps], jnp.float32)
    # Static shape maxima rounded to buckets: per-graph masks enforce the
    # true L_i/M_i, so padding never changes results — it keeps compiled
    # shapes identical across tuning iterations (one XLA compile, reused).
    L_max = graph.bucket(max(p.L for p in ps), 16)
    M_max = graph.bucket(max(p.M for p in ps), 8)
    ctr = BuildCounters()
    tape = CounterTape()

    # ---- Initialization: deterministic shared random KNNG (Alg. 6 l.1-2) ---
    init_ids = graph.random_knng_ids(seed, n, M_max)          # shared prefix
    init_dist = graph.with_distances(data, init_ids, kform)
    gids, gdist = [], []
    for p in ps:
        dm = jnp.arange(M_max)[None, :] < p.M
        gids.append(jnp.where(dm, init_ids, INVALID))
        gdist.append(jnp.where(dm, init_dist, jnp.inf))
    g = MultiGraph(ids=jnp.stack(gids), dist=jnp.stack(gdist))
    ctr.init_base += sum(n * p.M for p in ps)
    ctr.init += n * M_max if use_eso else ctr.init_base

    ep = int(graph.medoid(data, kform))                       # Alg. 6 l.3
    hops = max_hops or search.default_max_hops(L_max)
    step_kw = dict(ef_max=L_max, max_hops=hops, share_cache=use_eso,
                   use_epo=use_epo, metric=kform,
                   visited_impl=visited_impl,
                   expand_width=expand_width, k_in=k_in, m_max=M_max)

    # ---- main pass (Alg. 6 l.4-12), batched ---------------------------------
    if build_impl == "fused":
        # ONE compiled dispatch for the whole pass: lax.fori_loop over
        # batches on device, counter rows logged into a device array
        # (DESIGN.md §12).  Bit-identical to the per_batch loop below.
        new_ids, new_dist, log = build_lib.fused_vamana_pass(
            g.ids, g.dist, data, L, M, alpha, jnp.int32(ep),
            batch_size=batch_size, **step_kw)
        g = MultiGraph(ids=new_ids, dist=new_dist)
        tape.log_many(log)
    else:
        for off in range(0, n, batch_size):
            ids_np = np.arange(off, min(off + batch_size, n), dtype=np.int32)
            b = batch_size
            u = jnp.full((b,), n, jnp.int32).at[:len(ids_np)].set(ids_np)
            row_mask = jnp.arange(b) < len(ids_np)
            queries = data[jnp.minimum(u, n - 1)]
            entry = jnp.broadcast_to(jnp.int32(ep), (b, m))

            res = search.beam_search(
                g.ids, data, queries, jnp.where(row_mask, u, INVALID),
                row_mask, L, entry, ef_max=L_max, max_hops=hops,
                share_cache=use_eso, metric=kform,
                visited_impl=visited_impl, expand_width=expand_width)

            cand_ids = jnp.transpose(res.pool_ids, (1, 0, 2))  # (m, b, L_max)
            cand_dist = jnp.transpose(res.pool_dist, (1, 0, 2))
            valid = cand_ids != INVALID
            pruned, nb, nc = prune.multi_prune(
                data, cand_ids, cand_dist, valid, M, alpha,
                m_max=M_max, use_epo=use_epo, metric=kform)

            new_ids, new_dist, rev_checks = commit.commit_group(
                data, g.ids, g.dist, u, pruned, row_mask, M, alpha,
                k_in=k_in, m_max=M_max, metric=kform)
            g = MultiGraph(ids=new_ids, dist=new_dist)
            # device-side accumulation: no host round-trip per batch
            tape.log(res.n_fresh, res.n_computed,
                     nb + rev_checks, nc + rev_checks)

    tape.drain_into(ctr)          # the build's ONE counter host sync
    g = MultiGraph(ids=g.ids[inv_order], dist=g.dist[inv_order])
    return BuildResult(g=g, entry=ep, counters=ctr, params=params,
                       metric=met.name)


def build_vamana(data, p: VamanaParams, **kw) -> BuildResult:
    """Single-graph build (baseline estimation path: no sharing possible)."""
    kw.setdefault("use_eso", False)
    kw.setdefault("use_epo", False)
    return build_multi_vamana(data, [p], **kw)
