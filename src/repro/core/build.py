"""Fused multi-graph build step — one compiled dispatch per insertion batch.

The paper's headline speedup comes from constructing many PGs
simultaneously so repeated computation is paid once (PAPER.md); the
per-batch host loop the builders originally ran paid that saving back in
dispatch overhead: every batch issued one ``beam_search`` dispatch, m
``rng_prune`` dispatches, m ``add_reverse_edges`` dispatches, and dozens of
eager ops (transposes, candidate merges, scatter commits) — roughly
``1 + 3m`` compiled dispatches plus eager-op traffic per batch, with
``int(...)`` counter casts blocking the host after each one.

This module fuses the whole search → mPrune → commit batch step into ONE
jitted function (DESIGN.md §12):

  ``insert_batch``      the Vamana/HNSW-shaped step (search the evolving
                        graph, prune, commit) — a single dispatch per batch,
                        counters returned as an int32[4] device row.
  ``nsg_insert_batch``  the NSG-shaped step (search a *static* KNNG, merge
                        each node's own KNNG row into the candidates, prune,
                        commit) — same single-dispatch contract.
  ``fused_vamana_pass`` the device-resident outer loop: ``lax.fori_loop``
                        over ALL insertion batches inside one jit, writing
                        per-batch counter rows into a preallocated log —
                        the host dispatches once per build pass and never
                        blocks mid-build.

The fused step *traces the same functions the per_batch loop dispatches*
(``search.beam_search``, ``prune.multi_prune``, ``commit.commit_group``)
— there is no separate fused algorithm to drift.  Graphs and counters are
bit-identical to per_batch at test scale (pinned by
tests/test_fused_build.py), and ``fused_vamana_pass`` is exactly
bit-identical to dispatching ``insert_batch`` per batch at every scale
measured.  Versus the *legacy staged* path there is one documented FP
deviation (DESIGN.md §12): per_batch runs the prune stage's
candidate-distance reduction eagerly, the fused step compiles it, and the
differing accumulation orders flip ppm-level near-ties in the dominance
checks (≤4e-5 of prune counters at n=20k; bounded per cell by
benchmarks/build_bench.py, exact deltas recorded in BENCH_build.json).

Shapes are static and bucketed exactly as in the per_batch path
(``graph.bucket`` on L_max/M_max, fixed ``batch_size``), so one XLA compile
of the fused step is reused across every batch of every tuning iteration
that shares the bucketed shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import commit, counters as counters_lib, prune, search
from repro.core.graph import INVALID

BUILD_IMPLS = ("per_batch", "fused")


def resolve_build_impl(build_impl: str) -> str:
    if build_impl not in BUILD_IMPLS:
        raise ValueError(
            f"build_impl {build_impl!r} not in {BUILD_IMPLS}")
    return build_impl


def _insert_step(graph_ids, graph_dist, data, u, row_mask, queries, L, M,
                 alpha, entry, cache_d, cache_has, *, ef_max, max_hops,
                 share_cache, use_epo, metric, visited_impl, expand_width,
                 k_in, m_max):
    """Traced body of one insertion batch: search → mPrune → commit.

    Literally the statements the per_batch builder loop runs, gathered into
    one traceable function — counters come back as device scalars instead
    of Python-int mutations, everything else is unchanged (bit-identity by
    construction).  Returns ``(new_ids, new_dist, ctr_row, top_ids,
    cache_d, cache_has)`` where ``ctr_row`` is the int32[4] CounterTape row
    and ``top_ids`` is each (query, graph)'s closest pool entry (HNSW's
    next-layer entry points; Vamana ignores it).
    """
    qids = jnp.where(row_mask, u, INVALID)
    res = search.beam_search(
        graph_ids, data, queries, qids, row_mask, L, entry,
        cache_d, cache_has, ef_max=ef_max, max_hops=max_hops,
        share_cache=share_cache, metric=metric, visited_impl=visited_impl,
        expand_width=expand_width)

    cand_ids = jnp.transpose(res.pool_ids, (1, 0, 2))     # (m, b, ef_max)
    cand_dist = jnp.transpose(res.pool_dist, (1, 0, 2))
    valid = cand_ids != INVALID
    pruned, nb, nc = prune.multi_prune(
        data, cand_ids, cand_dist, valid, M, alpha,
        m_max=m_max, use_epo=use_epo, metric=metric)

    new_ids, new_dist, rev_checks = commit.commit_group(
        data, graph_ids, graph_dist, u, pruned, row_mask, M, alpha,
        k_in=k_in, m_max=m_max, metric=metric)
    ctr_row = counters_lib.step_row(res.n_fresh, res.n_computed,
                                    nb + rev_checks, nc + rev_checks)
    return (new_ids, new_dist, ctr_row, res.pool_ids[:, :, 0],
            res.cache_d, res.cache_has)


insert_batch = jax.jit(
    _insert_step,
    static_argnames=("ef_max", "max_hops", "share_cache", "use_epo",
                     "metric", "visited_impl", "expand_width", "k_in",
                     "m_max"))
insert_batch.__doc__ = (
    "One compiled dispatch per insertion batch: jitted _insert_step.  The "
    "dispatch-count contract tests/test_fused_build.py pins — after "
    "warmup, a batch step invokes NO other jitted callable at the Python "
    "level (DESIGN.md §12).")


def _nsg_step(search_graph_ids, graph_ids, graph_dist, knn_ids, knn_dist,
              data, u, row_mask, queries, L, M, alpha, K, entry, *, ef_max,
              max_hops, share_cache, use_epo, metric, visited_impl,
              expand_width, k_in, m_max, k_max):
    """Traced body of one NSG insertion batch (search on the static KNNG,
    candidates = search pool ∪ the node's own KNNG row, prune, commit).

    Same statement-for-statement transplant from ``nsg.build_multi_nsg``'s
    per_batch loop as ``_insert_step`` is from Vamana's — the KNNG-row
    merge/dedup included — so graphs and counters stay bit-identical."""
    n = data.shape[0]
    qids = jnp.where(row_mask, u, INVALID)
    res = search.beam_search(
        search_graph_ids, data, queries, qids, row_mask, L, entry,
        ef_max=ef_max, max_hops=max_hops, share_cache=share_cache,
        metric=metric, visited_impl=visited_impl,
        expand_width=expand_width)

    u_safe = jnp.minimum(u, n - 1)
    m = graph_ids.shape[0]
    own_ids = jnp.broadcast_to(knn_ids[u_safe][None],
                               (m,) + knn_ids[u_safe].shape)
    own_dist = jnp.broadcast_to(knn_dist[u_safe][None], own_ids.shape)
    kmask = jnp.arange(k_max)[None, None, :] < K[:, None, None]
    own_ids = jnp.where(kmask & row_mask[None, :, None], own_ids, INVALID)
    own_dist = jnp.where(own_ids != INVALID, own_dist, jnp.inf)
    cand_ids = jnp.concatenate(
        [jnp.transpose(res.pool_ids, (1, 0, 2)), own_ids], axis=-1)
    cand_dist = jnp.concatenate(
        [jnp.transpose(res.pool_dist, (1, 0, 2)), own_dist], axis=-1)
    srt = jnp.argsort(cand_dist, axis=-1)
    cand_ids = jnp.take_along_axis(cand_ids, srt, axis=-1)
    cand_dist = jnp.take_along_axis(cand_dist, srt, axis=-1)
    eq = cand_ids[:, :, None, :] == cand_ids[:, :, :, None]
    c = cand_ids.shape[-1]
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    dup = jnp.any(eq & tri[None, None], axis=-1)
    cand_ids = jnp.where(dup, INVALID, cand_ids)
    cand_dist = jnp.where(dup, jnp.inf, cand_dist)
    valid = cand_ids != INVALID
    pruned, nb, nc = prune.multi_prune(
        data, cand_ids, cand_dist, valid, M, alpha,
        m_max=m_max, use_epo=use_epo, metric=metric)

    new_ids, new_dist, rev_checks = commit.commit_group(
        data, graph_ids, graph_dist, u, pruned, row_mask, M, alpha,
        k_in=k_in, m_max=m_max, metric=metric)
    ctr_row = counters_lib.step_row(res.n_fresh, res.n_computed,
                                    nb + rev_checks, nc + rev_checks)
    return new_ids, new_dist, ctr_row


nsg_insert_batch = jax.jit(
    _nsg_step,
    static_argnames=("ef_max", "max_hops", "share_cache", "use_epo",
                     "metric", "visited_impl", "expand_width", "k_in",
                     "m_max", "k_max"))


@functools.partial(
    jax.jit,
    static_argnames=("batch_size", "ef_max", "max_hops", "share_cache",
                     "use_epo", "metric", "visited_impl", "expand_width",
                     "k_in", "m_max"))
def fused_vamana_pass(graph_ids, graph_dist, data, L, M, alpha, ep, *,
                      batch_size, ef_max, max_hops, share_cache, use_epo,
                      metric, visited_impl, expand_width, k_in, m_max):
    """Device-resident Vamana main pass: every insertion batch inside ONE
    compiled dispatch (``lax.fori_loop`` over batches).

    The loop body reproduces the per_batch host loop's batch construction
    exactly — ``u`` padded with ``n`` past the corpus, padding rows masked,
    queries gathered at ``min(u, n-1)`` — then runs ``_insert_step``, so
    the final graphs and the per-batch counter rows are bit-identical to
    ``build_impl="per_batch"``.  Counter rows land in a preallocated
    int32[n_batches, 4] log (no int64 carry needed: the host sums the
    fetched log in int64), returned alongside the graphs for one
    ``CounterTape.log_many`` + end-of-build sync.
    """
    n = data.shape[0]
    m = graph_ids.shape[0]
    n_batches = -(-n // batch_size)
    log = jnp.zeros((n_batches, 4), jnp.int32)

    def body(t, carry):
        ids, dist, log = carry
        off = t * batch_size
        u = off + jnp.arange(batch_size, dtype=jnp.int32)
        row_mask = u < n
        u = jnp.where(row_mask, u, n)
        queries = data[jnp.minimum(u, n - 1)]
        entry = jnp.broadcast_to(ep.astype(jnp.int32), (batch_size, m))
        ids, dist, row, _, _, _ = _insert_step(
            ids, dist, data, u, row_mask, queries, L, M, alpha, entry,
            None, None, ef_max=ef_max, max_hops=max_hops,
            share_cache=share_cache, use_epo=use_epo, metric=metric,
            visited_impl=visited_impl, expand_width=expand_width,
            k_in=k_in, m_max=m_max)
        return ids, dist, log.at[t].set(row)

    graph_ids, graph_dist, log = jax.lax.fori_loop(
        0, n_batches, body, (graph_ids, graph_dist, log))
    return graph_ids, graph_dist, log
