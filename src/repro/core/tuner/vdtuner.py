"""VDTuner-style MOBO recommendation (surrogate GPs + EHVI / mEHVI).

Implements the paper's recommendation layer: two GP surrogates map encoded
construction parameters to normalized (QPS, Recall@k) (Eq. 1 normalization
by the most balanced non-dominated point), and EHVI picks the next
candidate.  ``recommend(batch=1)`` is stock VDTuner; ``batch=m`` is the
paper's mEHVI extension (§IV-B) used by FastPGT.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.tuner import ehvi, gp as gplib, pareto
from repro.core.tuner.params import ParamSpace


@dataclasses.dataclass
class MOBOState:
    x: list           # encoded configs, list[np.ndarray (d,)]
    y: list           # list[(qps, recall)] raw observations

    def observe(self, x01: np.ndarray, obj: tuple[float, float]):
        self.x.append(np.asarray(x01, np.float64))
        self.y.append((float(obj[0]), float(obj[1])))


def _normalized_objectives(y: np.ndarray) -> np.ndarray:
    """VDTuner Eq. (1): divide by the most balanced non-dominated point."""
    bal = pareto.balanced_point(y)
    bal = np.where(np.abs(bal) < 1e-9, 1.0, bal)
    return y / bal[None, :]


def recommend(
    state: MOBOState,
    space: ParamSpace,
    rng: np.random.Generator,
    *,
    batch: int = 1,
    pool: int = 96,
    mc_samples: int = 64,
    seed: int = 0,
) -> list[np.ndarray]:
    """Return ``batch`` encoded candidates maximizing (m)EHVI."""
    y = np.asarray(state.y, np.float64)
    yn = _normalized_objectives(y)
    x = np.asarray(state.x, np.float64)

    gp_qps = gplib.fit(x, yn[:, 0])
    gp_rec = gplib.fit(x, yn[:, 1])
    front = pareto.pareto_front(yn)
    ref = pareto.default_reference(yn)

    # Candidate pool: random + perturbations of current front members.
    cands = [space.sample(rng, pool)]
    front_mask = pareto.non_dominated_mask(y)
    for xf in x[front_mask][:8]:
        cands.append(space.perturb(rng, np.tile(xf, (8, 1)), 0.08))
    cands = np.concatenate(cands, axis=0)
    # Drop near-duplicates of evaluated points.
    d2 = ((cands[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    cands = cands[d2.min(axis=1) > 1e-6]
    if cands.shape[0] == 0:
        cands = space.sample(rng, pool)

    key = jax.random.PRNGKey(seed)
    if batch == 1:
        scores = ehvi.ehvi_scores(gp_qps, gp_rec, cands, front, ref, key,
                                  n_samples=mc_samples)
        return [cands[int(np.argmax(scores))]]
    idx = ehvi.select_batch_mehvi(gp_qps, gp_rec, cands, front, ref,
                                  batch, key, n_samples=mc_samples)
    return [cands[i] for i in idx]
