"""Baseline recommendation strategies: RandomSearch, GridSearch, OtterTune.

All share FastPGT's estimation layer (estimator.estimate), so enabling
``group_size > 1`` with ESO/EPO turns RandomSearch into the paper's
RandomSearch+ (Table VI) — the framework is model-agnostic.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.tuner import gp as gplib
from repro.core.tuner.params import ParamSpace


def random_candidates(space: ParamSpace, rng: np.random.Generator,
                      n: int) -> list[np.ndarray]:
    return list(space.sample(rng, n))


def grid_candidates(space: ParamSpace, budget: int) -> list[np.ndarray]:
    per_dim = max(2, int(round(budget ** (1.0 / space.d))))
    g = space.grid(per_dim)
    return list(g[:budget])


@dataclasses.dataclass
class OtterTuneState:
    """OtterTune-style single-objective GPR tuner.

    Scalarizes to 'QPS subject to Recall >= target' with a smooth penalty
    (OtterTune optimizes one workload metric with GPR + aggressive
    exploitation); acquisition is UCB.
    """
    target_recall: float
    x: list = dataclasses.field(default_factory=list)
    y: list = dataclasses.field(default_factory=list)

    def scalarize(self, qps: float, recall: float) -> float:
        pen = min(1.0, recall / max(self.target_recall, 1e-9)) ** 8
        return qps * pen

    def observe(self, x01: np.ndarray, qps: float, recall: float):
        self.x.append(np.asarray(x01, np.float64))
        self.y.append(self.scalarize(qps, recall))

    def recommend(self, space: ParamSpace, rng: np.random.Generator,
                  *, pool: int = 96, beta: float = 2.0) -> np.ndarray:
        x = np.asarray(self.x)
        y = np.asarray(self.y)
        g = gplib.fit(x, y)
        cands = space.sample(rng, pool)
        mean, var = gplib.predict(g, cands)
        ucb = np.asarray(mean) + beta * np.sqrt(np.asarray(var))
        return cands[int(np.argmax(ucb))]
