"""Pareto utilities: non-dominated sort and exact 2-D hypervolume.

Objectives are MAXIMIZED (QPS, Recall@k).  The reference point r lower-bounds
the hypervolume (VDTuner uses a preset r; we default to the observed minima
minus a margin).
"""
from __future__ import annotations

import numpy as np


def non_dominated_mask(points: np.ndarray) -> np.ndarray:
    """bool[n] — True where no other point dominates (maximization)."""
    p = np.asarray(points, dtype=np.float64)
    n = p.shape[0]
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dom = np.all(p >= p[i], axis=1) & np.any(p > p[i], axis=1)
        if dom.any():
            mask[i] = False
    return mask


def pareto_front(points: np.ndarray) -> np.ndarray:
    return np.asarray(points)[non_dominated_mask(points)]


def hypervolume_2d(points: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume of the region dominated by ``points`` above ref.

    Sweep: sort the non-dominated front descending by the first objective and
    accumulate rectangles.
    """
    p = np.asarray(points, dtype=np.float64)
    if p.size == 0:
        return 0.0
    p = p[(p[:, 0] > ref[0]) & (p[:, 1] > ref[1])]
    if p.shape[0] == 0:
        return 0.0
    p = pareto_front(p)
    p = p[np.argsort(-p[:, 0])]
    hv = 0.0
    prev_y = ref[1]
    for x, y in p:
        if y > prev_y:
            hv += (x - ref[0]) * (y - prev_y)
            prev_y = y
    return float(hv)


def balanced_point(points: np.ndarray) -> np.ndarray:
    """VDTuner Eq. (1) normalizer: the most balanced non-dominated point.

    argmax over the front of 1 / |qps/qps_max - recall/recall_max|.
    """
    front = pareto_front(points)
    mx = front.max(axis=0)
    mx = np.where(mx <= 0, 1.0, mx)
    gap = np.abs(front[:, 0] / mx[0] - front[:, 1] / mx[1])
    return front[np.argmin(gap)]


def default_reference(points: np.ndarray, margin: float = 0.1) -> np.ndarray:
    p = np.asarray(points, dtype=np.float64)
    lo = p.min(axis=0)
    span = np.maximum(p.max(axis=0) - lo, 1e-9)
    return lo - margin * span
