"""Parameter spaces and PG adapters for the tuners.

One ``ParamSpace`` per PG type (HNSW / Vamana / NSG) with the paper's knobs
(R removed per Theorem 1).  Tuners work in the unit hypercube; ``decode``
maps to integer/continuous construction parameters.  ``scale`` shrinks the
ranges for laptop-size datasets while keeping the same relative geometry.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from repro.core import hnsw as hnswlib
from repro.core import nsg as nsglib
from repro.core import vamana as vamanalib


@dataclasses.dataclass(frozen=True)
class ParamDim:
    name: str
    lo: float
    hi: float
    is_int: bool = True
    log: bool = False

    def decode(self, v01: float):
        lo, hi = self.lo, self.hi
        if self.log:
            x = math.exp(math.log(lo) + v01 * (math.log(hi) - math.log(lo)))
        else:
            x = lo + v01 * (hi - lo)
        return int(round(x)) if self.is_int else float(x)

    def encode(self, x: float) -> float:
        if self.log:
            return ((math.log(x) - math.log(self.lo))
                    / (math.log(self.hi) - math.log(self.lo)))
        return (x - self.lo) / (self.hi - self.lo)


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    pg: str
    dims: tuple[ParamDim, ...]
    metric: str = "l2"      # workload axis: the metric the tuned index serves

    @property
    def d(self) -> int:
        return len(self.dims)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.random((n, self.d))

    def grid(self, per_dim: int) -> np.ndarray:
        axes = [np.linspace(0.0, 1.0, per_dim) for _ in self.dims]
        mesh = np.meshgrid(*axes, indexing="ij")
        return np.stack([m.reshape(-1) for m in mesh], axis=1)

    def decode(self, x01: np.ndarray) -> dict[str, Any]:
        return {d.name: d.decode(float(v)) for d, v in zip(self.dims, x01)}

    def perturb(self, rng: np.random.Generator, x01: np.ndarray,
                sigma: float = 0.1) -> np.ndarray:
        return np.clip(x01 + rng.normal(0, sigma, x01.shape), 0.0, 1.0)


def space(pg: str, scale: float = 1.0, metric: str = "l2") -> ParamSpace:
    """Paper-faithful knobs; ``scale`` shrinks upper bounds for small n.

    ``metric`` tags the space with the workload's distance metric; it is not
    a tunable dimension (changing it changes the ground truth, not the knobs)
    but rides along so build/eval stay consistent with the recommendation.
    """
    s = scale
    if pg == "hnsw":
        dims = (ParamDim("efc", 16, max(32, int(512 * s)), log=True),
                ParamDim("M", 4, max(8, int(64 * s)), log=True))
    elif pg == "vamana":
        dims = (ParamDim("L", 16, max(32, int(512 * s)), log=True),
                ParamDim("M", 4, max(8, int(64 * s)), log=True),
                ParamDim("alpha", 1.0, 2.0, is_int=False))
    elif pg == "nsg":
        dims = (ParamDim("K", 8, max(16, int(64 * s)), log=True),
                ParamDim("L", 16, max(32, int(512 * s)), log=True),
                ParamDim("M", 4, max(8, int(64 * s)), log=True))
    else:
        raise ValueError(f"unknown pg type {pg!r}")
    return ParamSpace(pg=pg, dims=dims, metric=metric)


def to_build_params(pg: str, cfg: dict[str, Any]):
    if pg == "hnsw":
        return hnswlib.HNSWParams(efc=cfg["efc"], M=cfg["M"])
    if pg == "vamana":
        return vamanalib.VamanaParams(L=cfg["L"], M=cfg["M"],
                                      alpha=cfg["alpha"])
    if pg == "nsg":
        return nsglib.NSGParams(K=cfg["K"], L=cfg["L"], M=cfg["M"])
    raise ValueError(pg)


def build_many(pg: str, data, build_params: list, *, seed: int,
               use_eso: bool, use_epo: bool, batch_size: int,
               metric: str = "l2", visited_impl: str = "dense",
               expand_width: int = 1, build_impl: str = "per_batch"):
    """Dispatch to the multi-builders. Returns the per-PG BuildResult.

    ``expand_width`` defaults to 1: construction follows the paper's
    sequential best-first schedule so §2.1 bit-identity and the paper-exact
    #dist counters hold (DESIGN.md §10).  ``build_impl`` selects the batch
    execution strategy — "fused" runs each batch step (Vamana: the whole
    pass) as one compiled dispatch; graphs and counters match per_batch up
    to a documented ppm-level FP-tie deviation (DESIGN.md §12).
    """
    kw = dict(seed=seed, use_eso=use_eso, use_epo=use_epo,
              batch_size=batch_size, metric=metric,
              visited_impl=visited_impl, expand_width=expand_width,
              build_impl=build_impl)
    if pg == "hnsw":
        return hnswlib.build_multi_hnsw(data, build_params, **kw)
    if pg == "vamana":
        return vamanalib.build_multi_vamana(data, build_params, **kw)
    if pg == "nsg":
        return nsglib.build_multi_nsg(data, build_params, **kw)
    raise ValueError(pg)
