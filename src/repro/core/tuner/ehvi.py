"""EHVI / mEHVI acquisition (paper §IV-B, Eq. 2).

Standard EHVI recommends one candidate per iteration; FastPGT's mEHVI
estimates the *joint* expected hypervolume improvement of a whole batch by
Monte-Carlo: draw joint GP posterior samples at the m candidates (full
posterior covariance per objective), compute the exact 2-D HVI of each
sample against the current front, and average.  Batch selection is greedy:
grow the batch one candidate at a time, scoring each extension by its joint
mEHVI (common random numbers keep the comparison low-variance).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tuner import gp as gplib
from repro.core.tuner import pareto


def _mc_joint_hvi(gp_qps: gplib.GPState, gp_rec: gplib.GPState,
                  cand: np.ndarray, front: np.ndarray, ref: np.ndarray,
                  key: jax.Array, n_samples: int) -> float:
    """Monte-Carlo E[HV(front ∪ f(cand)) - HV(front)] for a candidate set."""
    k1, k2 = jax.random.split(key)
    s_qps = np.asarray(gplib.sample(gp_qps, jnp.asarray(cand), k1, n_samples))
    s_rec = np.asarray(gplib.sample(gp_rec, jnp.asarray(cand), k2, n_samples))
    base = pareto.hypervolume_2d(front, ref)
    total = 0.0
    for s in range(n_samples):
        pts = np.concatenate(
            [front, np.stack([s_qps[s], s_rec[s]], axis=1)], axis=0)
        total += pareto.hypervolume_2d(pts, ref) - base
    return total / n_samples


def ehvi_scores(gp_qps, gp_rec, cands: np.ndarray, front: np.ndarray,
                ref: np.ndarray, key: jax.Array, n_samples: int = 96
                ) -> np.ndarray:
    """Per-candidate (m=1) EHVI — vectorized MC over all candidates at once.

    Uses marginal (per-candidate) posteriors; exact for single-candidate
    EHVI since HVI of one point needs no cross-candidate correlation.
    """
    xq = jnp.asarray(cands)
    mean_q, var_q = gplib.predict(gp_qps, xq)
    mean_r, var_r = gplib.predict(gp_rec, xq)
    k1, k2 = jax.random.split(key)
    zq = jax.random.normal(k1, (n_samples, cands.shape[0]))
    zr = jax.random.normal(k2, (n_samples, cands.shape[0]))
    s_q = np.asarray(mean_q[None] + jnp.sqrt(var_q)[None] * zq)
    s_r = np.asarray(mean_r[None] + jnp.sqrt(var_r)[None] * zr)
    base = pareto.hypervolume_2d(front, ref)
    scores = np.zeros(cands.shape[0])
    for c in range(cands.shape[0]):
        tot = 0.0
        for s in range(n_samples):
            pts = np.concatenate([front, [[s_q[s, c], s_r[s, c]]]], axis=0)
            tot += pareto.hypervolume_2d(pts, ref) - base
        scores[c] = tot / n_samples
    return scores


def select_batch_mehvi(
    gp_qps, gp_rec, cands: np.ndarray, front: np.ndarray, ref: np.ndarray,
    batch: int, key: jax.Array, n_samples: int = 64,
) -> list[int]:
    """Greedy mEHVI batch selection (Eq. 2): maximize joint HVI of the set."""
    chosen: list[int] = []
    remaining = list(range(cands.shape[0]))
    for step in range(batch):
        key, sub = jax.random.split(key)
        best_i, best_v = None, -np.inf
        for i in remaining:
            idx = chosen + [i]
            v = _mc_joint_hvi(gp_qps, gp_rec, cands[idx], front, ref,
                              sub, n_samples)
            if v > best_v:
                best_i, best_v = i, v
        chosen.append(best_i)
        remaining.remove(best_i)
    return chosen
