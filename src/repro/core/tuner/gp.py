"""Pure-JAX Gaussian-process regression (VDTuner's surrogate model).

RBF kernel with ARD lengthscales; hyperparameters (log lengthscales, log
signal variance, log noise) fit by Adam on the exact log marginal likelihood.
Inputs live in the unit hypercube (ParamSpace.encode); targets are
standardized internally.  Everything is f64-free and Cholesky-based with a
jitter floor, sized for the O(100) observations a tuning run produces.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class GPState:
    x: jax.Array          # (n, d) observed inputs in [0, 1]^d
    y: jax.Array          # (n,) raw targets
    log_ls: jax.Array     # (d,)
    log_sf: jax.Array     # ()
    log_sn: jax.Array     # ()
    y_mean: jax.Array
    y_std: jax.Array
    chol: jax.Array       # (n, n) cholesky of K + sn I
    alpha: jax.Array      # (n,) K^-1 (y - mean)/std


def _kernel(x1, x2, log_ls, log_sf):
    ls = jnp.exp(log_ls)
    a = x1 / ls
    b = x2 / ls
    d2 = (jnp.sum(a * a, -1, keepdims=True) + jnp.sum(b * b, -1)
          - 2.0 * (a @ b.T))
    return jnp.exp(log_sf) * jnp.exp(-0.5 * jnp.maximum(d2, 0.0))


def _nll(params, x, y):
    log_ls, log_sf, log_sn = params
    n = x.shape[0]
    k = _kernel(x, x, log_ls, log_sf) + (jnp.exp(log_sn) + 1e-6) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha + jnp.sum(jnp.log(jnp.diag(chol)))
            + 0.5 * n * jnp.log(2 * jnp.pi))


@functools.partial(jax.jit, static_argnames=("steps",))
def _fit_params(x, y, log_ls0, log_sf0, log_sn0, *, steps: int = 80):
    params = (log_ls0, log_sf0, log_sn0)
    adam_m = jax.tree_util.tree_map(jnp.zeros_like, params)
    adam_v = jax.tree_util.tree_map(jnp.zeros_like, params)
    lr, b1, b2, eps = 0.08, 0.9, 0.999, 1e-8
    grad_fn = jax.grad(_nll)

    def body(i, st):
        params, m, v = st
        g = grad_fn(params, x, y)
        m = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: b2 * a + (1 - b2) * b * b,
                                   v, g)
        t = i + 1.0
        def upd(p, mi, vi):
            mh = mi / (1 - b1 ** t)
            vh = vi / (1 - b2 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + eps)
        params = jax.tree_util.tree_map(upd, params, m, v)
        return params, m, v

    params, _, _ = jax.lax.fori_loop(0., float(steps), body,
                                     (params, adam_m, adam_v))
    return params


def fit(x: jax.Array, y: jax.Array, *, steps: int = 80) -> GPState:
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    y_mean = jnp.mean(y)
    y_std = jnp.maximum(jnp.std(y), 1e-6)
    ys = (y - y_mean) / y_std
    d = x.shape[1]
    log_ls, log_sf, log_sn = _fit_params(
        x, ys, jnp.zeros((d,)) - 1.0, jnp.float32(0.0), jnp.float32(-4.0),
        steps=steps)
    n = x.shape[0]
    k = _kernel(x, x, log_ls, log_sf) + (jnp.exp(log_sn) + 1e-6) * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), ys)
    return GPState(x=x, y=y, log_ls=log_ls, log_sf=log_sf, log_sn=log_sn,
                   y_mean=y_mean, y_std=y_std, chol=chol, alpha=alpha)


def predict(gp: GPState, xq: jax.Array, *, full_cov: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """Posterior mean (q,) and variance (q,) — or covariance (q, q)."""
    xq = jnp.asarray(xq, jnp.float32)
    ks = _kernel(gp.x, xq, gp.log_ls, gp.log_sf)          # (n, q)
    mean = gp.y_mean + gp.y_std * (ks.T @ gp.alpha)
    v = jax.scipy.linalg.solve_triangular(gp.chol, ks, lower=True)
    if full_cov:
        kq = _kernel(xq, xq, gp.log_ls, gp.log_sf)
        cov = (kq - v.T @ v) * gp.y_std ** 2
        cov = cov + 1e-8 * jnp.eye(xq.shape[0])
        return mean, cov
    kq = jnp.exp(gp.log_sf) * jnp.ones(xq.shape[0])
    var = jnp.maximum(kq - jnp.sum(v * v, axis=0), 1e-10) * gp.y_std ** 2
    return mean, var


def sample(gp: GPState, xq: jax.Array, key: jax.Array, n_samples: int
           ) -> jax.Array:
    """(n_samples, q) joint posterior samples (full covariance)."""
    mean, cov = predict(gp, xq, full_cov=True)
    chol = jnp.linalg.cholesky(cov)
    z = jax.random.normal(key, (n_samples, xq.shape[0]))
    return mean[None, :] + z @ chol.T
