"""Parameter estimation — the cost the paper attacks (§IV-C..F).

``estimate`` builds the PGs for a batch of recommended configurations and
measures each graph's (QPS, Recall@k) frontier.  ``group_size`` controls the
paper's sharing: 1 = the baseline estimation every prior tuner uses (each PG
built independently); >1 = FastPGT's simultaneous multi-PG construction with
ESO/EPO.  Wall time and logical #dist are accounted per phase so Table I/IV
and the ablation (Table V) read straight off the returned record.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core import eval as evallib
from repro.core import hnsw as hnswlib
from repro.core import metric as metric_lib
from repro.core.counters import BuildCounters
from repro.core.tuner import params as pspace


@dataclasses.dataclass
class Estimate:
    cfg: dict[str, Any]
    qps: float
    recall: float
    points: list          # full (ef, recall, qps) sweep
    def objectives(self) -> tuple[float, float]:
        return self.qps, self.recall


@dataclasses.dataclass
class EstimationRecord:
    estimates: list[Estimate]
    counters: BuildCounters
    build_seconds: float
    eval_seconds: float
    n_dist_eval: int = 0

    @property
    def seconds(self) -> float:
        return self.build_seconds + self.eval_seconds


def resolve_ef_grid(k: int, ef_grid: list[int] | None) -> list[int]:
    """Default + validate the evaluation ef grid BEFORE any build runs.

    Every ef in the grid must hold at least k candidates (search pools
    return only ef ids); an undersized ef would otherwise surface as the
    ``knn_search`` k>ef error in the middle of estimation, with the
    multi-PG builds for the group already paid for.
    """
    ef_grid = ef_grid or [max(10, k), 2 * k, 4 * k, 8 * k]
    if k > min(ef_grid):
        raise ValueError(
            f"k={k} > min(ef_grid)={min(ef_grid)}: every ef in the grid "
            f"must be >= k (a search pool holds only ef candidates), and "
            f"this is checked before any PG is built so an undersized grid "
            f"cannot waste a build; raise the offending ef or lower k")
    return ef_grid


def _eval_one(pg, build_res, gi, data, queries, gt, k, ef_grid, timing_reps,
              visited_impl="dense", expand_width=1):
    metric = build_res.metric     # search under the metric the graph records
    if pg == "hnsw":
        def fn(q, ef):
            return hnswlib.hnsw_search(build_res.g, gi, data, q, k, ef,
                                       metric=metric,
                                       visited_impl=visited_impl,
                                       expand_width=expand_width)
    else:
        def fn(q, ef):
            return evallib.flat_graph_search_fn(
                build_res.g, gi, data, build_res.entry, k, metric,
                visited_impl, expand_width)(q, ef)
    return evallib.evaluate_search_fn(fn, queries, gt, k, ef_grid,
                                      timing_reps=timing_reps)


def estimate(
    pg: str,
    data,
    queries,
    gt,
    cfgs: list[dict[str, Any]],
    *,
    k: int = 10,
    ef_grid: list[int] | None = None,
    group_size: int = 1,
    use_eso: bool = True,
    use_epo: bool = True,
    seed: int = 0,
    build_batch_size: int = 256,
    timing_reps: int = 1,
    metric: str = "l2",
    visited_impl: str = "dense",
    expand_width: int = 1,
    build_impl: str = "per_batch",
) -> EstimationRecord:
    """Estimate the quality of each configuration in ``cfgs``.

    ``gt`` must be metric-correct ground truth (eval.ground_truth(...,
    metric=metric)) so (QPS, Recall) frontiers are comparable across metrics.
    ``visited_impl`` selects the search visit-state representation for both
    build and evaluation searches; "dense" (default) keeps the paper-exact
    #dist counters the tables report, "hash" estimates with the O(ef)
    serving memory profile (DESIGN.md §9).  ``expand_width`` likewise
    applies to both (DESIGN.md §10); the default 1 keeps estimation
    paper-exact, while W > 1 estimates with the multi-expansion schedule
    serving will actually run (and speeds the measured QPS sweeps up).
    ``build_impl="fused"`` runs each group's build with single-dispatch
    batch steps — same graphs, same counters, less dispatch overhead
    (DESIGN.md §12).
    """
    ef_grid = resolve_ef_grid(k, ef_grid)
    # Prepare the data ONCE and hand the kernel form down: otherwise every
    # timed cosine search renormalizes the full (n, d) matrix in-jit,
    # deflating cosine QPS relative to l2/ip and skewing the frontiers the
    # tuner compares.
    met = metric_lib.resolve(metric)
    data = met.prepare(data)
    queries = met.prepare(queries)
    metric = met.kernel
    ctr = BuildCounters()
    estimates: list[Estimate] = []
    t_build = 0.0
    t_eval = 0.0
    n_dist_eval = 0
    group_size = max(1, group_size)

    for goff in range(0, len(cfgs), group_size):
        group = cfgs[goff:goff + group_size]
        bps = [pspace.to_build_params(pg, c) for c in group]
        t0 = time.perf_counter()
        res = pspace.build_many(
            pg, data, bps, seed=seed,
            use_eso=use_eso and len(group) > 1,
            use_epo=use_epo and len(group) > 1,
            batch_size=build_batch_size, metric=metric,
            visited_impl=visited_impl, expand_width=expand_width,
            build_impl=build_impl)
        t_build += time.perf_counter() - t0
        ctr = ctr.add(res.counters)
        t0 = time.perf_counter()
        for gi, cfg in enumerate(group):
            points = _eval_one(pg, res, gi, data, queries, gt, k, ef_grid,
                               timing_reps, visited_impl, expand_width)
            qps, recall = evallib.frontier_objectives(points)
            n_dist_eval += sum(p.n_dist for p in points)
            estimates.append(Estimate(cfg=cfg, qps=qps, recall=recall,
                                      points=points))
        t_eval += time.perf_counter() - t0
    return EstimationRecord(estimates=estimates, counters=ctr,
                            build_seconds=t_build, eval_seconds=t_eval,
                            n_dist_eval=n_dist_eval)


def make_dataset(n: int, d: int, nq: int, *, seed: int = 0,
                 n_clusters: int = 32, spread: float = 4.0):
    """Synthetic clustered dataset (Sift/Glove-like geometry, DESIGN.md §8)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)) * spread
    data = centers[rng.integers(0, n_clusters, n)] + rng.normal(size=(n, d))
    qs = centers[rng.integers(0, n_clusters, nq)] + rng.normal(size=(nq, d))
    return (jnp.asarray(data, jnp.float32), jnp.asarray(qs, jnp.float32))
