"""FastPGT — the end-to-end tuning framework (paper Fig. 3).

``tune`` runs the full recommend -> estimate -> refine loop for any of:

  mode='fastpgt'     mEHVI batch recommendation + grouped multi-PG builds
                     with ESO/EPO (the paper's method).
  mode='vdtuner'     sequential EHVI, independent builds (SOTA baseline).
  mode='random'      RandomSearch, independent builds.
  mode='random_plus' RandomSearch + grouped ESO/EPO builds (Table VI RS+).
  mode='grid'        GridSearch lattice, independent builds.
  mode='ottertune'   single-objective GPR + UCB, independent builds.

Every run records per-phase wall time (Recom. vs Est. — Table I), logical
#dist counters (Tables II/IV/V/VI) and the full observation history
(tuning-quality figures 7-9).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core.counters import BuildCounters
from repro.core.tuner import baselines, estimator, pareto, vdtuner
from repro.core.tuner import params as pspace


@dataclasses.dataclass
class TuneResult:
    mode: str
    pg: str
    metric: str
    cfgs: list[dict[str, Any]]
    objectives: list[tuple[float, float]]      # (qps, recall) per config
    counters: BuildCounters
    t_recommend: float
    t_estimate: float
    n_dist_eval: int

    @property
    def t_total(self) -> float:
        return self.t_recommend + self.t_estimate

    def best_qps_at(self, recall_target: float) -> float:
        ok = [q for q, r in self.objectives if r >= recall_target]
        return max(ok) if ok else 0.0

    def pareto_front(self) -> np.ndarray:
        return pareto.pareto_front(np.asarray(self.objectives))

    def summary(self) -> dict:
        return {
            "mode": self.mode, "pg": self.pg, "metric": self.metric,
            "n_configs": len(self.cfgs),
            "t_recommend_s": round(self.t_recommend, 3),
            "t_estimate_s": round(self.t_estimate, 3),
            "t_total_s": round(self.t_total, 3),
            "est_fraction": round(
                self.t_estimate / max(self.t_total, 1e-9), 4),
            "n_dist_build": self.counters.total,
            "n_dist_build_base": self.counters.total_base,
            "n_dist_eval": self.n_dist_eval,
        }


def tune(
    pg: str,
    data,
    queries,
    *,
    mode: str = "fastpgt",
    budget: int = 40,
    batch: int = 10,
    k: int = 10,
    seed: int = 0,
    scale: float = 0.25,
    init_random: int | None = None,
    use_eso: bool = True,
    use_epo: bool = True,
    build_batch_size: int = 256,
    ef_grid: list[int] | None = None,
    mc_samples: int = 48,
    timing_reps: int = 1,
    metric: str = "l2",
    visited_impl: str = "dense",
    expand_width: int = 1,
    build_impl: str = "per_batch",
) -> TuneResult:
    from repro.core import eval as evallib   # local: avoids cycles

    rng = np.random.default_rng(seed)
    ef_grid = estimator.resolve_ef_grid(k, ef_grid)   # fail fast, not mid-run
    space = pspace.space(pg, scale=scale, metric=metric)
    metric = space.metric          # single source of truth from here on
    gt = evallib.ground_truth(data, queries, k, metric=metric)
    init_random = init_random if init_random is not None else max(batch, 6)

    grouped = mode in ("fastpgt", "random_plus")
    group_size = batch if grouped else 1
    eso = use_eso and grouped
    epo = use_epo and grouped

    ctr = BuildCounters()
    cfgs_hist: list[dict] = []
    obj_hist: list[tuple[float, float]] = []
    x_hist: list[np.ndarray] = []
    t_rec = 0.0
    t_est = 0.0
    n_dist_eval = 0
    mobo = vdtuner.MOBOState(x=[], y=[])
    otter = baselines.OtterTuneState(target_recall=0.9)

    def run_estimation(xs: list[np.ndarray]):
        nonlocal t_est, ctr, n_dist_eval
        cfgs = [space.decode(x) for x in xs]
        t0 = time.perf_counter()
        rec = estimator.estimate(
            pg, data, queries, gt, cfgs, k=k, ef_grid=ef_grid,
            group_size=group_size, use_eso=eso, use_epo=epo, seed=seed,
            build_batch_size=build_batch_size, timing_reps=timing_reps,
            metric=metric, visited_impl=visited_impl,
            expand_width=expand_width, build_impl=build_impl)
        t_est += time.perf_counter() - t0
        ctr = ctr.add(rec.counters)
        n_dist_eval += rec.n_dist_eval
        for x, e in zip(xs, rec.estimates):
            cfgs_hist.append(e.cfg)
            obj_hist.append((e.qps, e.recall))
            x_hist.append(x)
            mobo.observe(x, (e.qps, e.recall))
            otter.observe(x, e.qps, e.recall)

    # ---- initial design -----------------------------------------------------
    if mode == "grid":
        all_x = baselines.grid_candidates(space, budget)
        while len(cfgs_hist) < len(all_x):
            run_estimation(all_x[len(cfgs_hist):len(cfgs_hist) + group_size])
    elif mode in ("random", "random_plus"):
        all_x = baselines.random_candidates(space, rng, budget)
        while len(cfgs_hist) < budget:
            run_estimation(all_x[len(cfgs_hist):len(cfgs_hist) + group_size])
    else:
        n0 = min(init_random, budget)
        run_estimation(baselines.random_candidates(space, rng, n0))
        # ---- model-guided loop ---------------------------------------------
        it = 0
        while len(cfgs_hist) < budget:
            want = min(batch if mode == "fastpgt" else 1,
                       budget - len(cfgs_hist))
            t0 = time.perf_counter()
            if mode == "ottertune":
                xs = [otter.recommend(space, rng)]
            else:
                xs = vdtuner.recommend(
                    mobo, space, rng, batch=want,
                    mc_samples=mc_samples, seed=seed + 17 * it)
            t_rec += time.perf_counter() - t0
            run_estimation(xs)
            it += 1

    return TuneResult(mode=mode, pg=pg, metric=metric, cfgs=cfgs_hist,
                      objectives=obj_hist, counters=ctr, t_recommend=t_rec,
                      t_estimate=t_est, n_dist_eval=n_dist_eval)
