"""HLO text analysis: trip-count-corrected FLOPs, bytes, collective bytes.

XLA's ``cost_analysis`` counts a while-loop body once (verified on this
backend — see EXPERIMENTS.md §Dry-run), so layer-scanned models undercount
by ~n_layers.  This analyzer parses ``compiled.as_text()``:

  * splits the module into computations,
  * per computation counts dot FLOPs (2 * prod(out) * contracted), op
    output bytes (write-traffic proxy), and collective result bytes by kind,
  * resolves ``calls=`` / ``body=`` / ``condition=`` edges,
  * extracts while trip counts from the loop condition's compare constant,
  * rolls everything up from ENTRY with body costs multiplied by trips.

Shapes in the partitioned module are per-device, so totals are per-chip —
exactly what the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+\[[\d,]*\])")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _shape_numel_bytes(text: str) -> tuple[int, int]:
    """(numel, bytes) of the first shape literal in ``text``."""
    m = _SHAPE_RE.search(text)
    if not m:
        return 0, 0
    dt, dims = m.group(1), m.group(2)
    numel = 1
    if dims:
        for d in dims.split(","):
            numel *= int(d)
    return numel, numel * _DTYPE_BYTES.get(dt, 4)


def _all_shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    out_bytes: float = 0.0      # top-level materializing ops only
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)      # fusions etc.
    whiles: list = dataclasses.field(default_factory=list)     # (body, cond)
    max_const: int = 1


# ops whose "output" is free (no HBM write): bookkeeping / aliasing
_FREE_OPS = ("get-tuple-element", "bitcast", "parameter", "constant(",
             "tuple(", "after-all", "reshape(", "copy-done", "copy-start")


def _dot_flops(line: str, symtab: dict[str, list[int]]) -> float:
    """2 * prod(output) * prod(contracted lhs dims).

    Compiled HLO omits operand types; the lhs shape comes from the
    computation-local symbol table of result shapes.
    """
    lhs_c = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    shapes = _all_shapes(line)
    if not shapes:
        return 0.0
    out_dims = shapes[0][1]
    numel_out = 1
    for d in out_dims:
        numel_out *= d
    contract = 1
    m = re.search(r"\bdot\(([^)]*)\)", line)
    if lhs_c and m:
        opnames = _OPERANDS_RE.findall(m.group(1))
        if opnames and opnames[0] in symtab:
            lhs_dims = symtab[opnames[0]]
            idxs = (lhs_c.group(1).split(",") if lhs_c.group(1) else [])
            for idx in idxs:
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * numel_out * contract


def _split_computations(text: str) -> tuple[list[tuple[str, list[str]]], str]:
    """[(name, body lines)], entry_name."""
    comps: list[tuple[str, list[str]]] = []
    entry_name = ""
    cur_name, cur_lines = None, []
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if line.endswith("{") and (s.startswith("%") or s.startswith("ENTRY")):
            is_entry = s.startswith("ENTRY")
            name = s.split()[1] if is_entry else s.split()[0]
            name = name.lstrip("%").split("(")[0].strip()
            if is_entry:
                entry_name = name
            cur_name, cur_lines = name, []
            continue
        if s == "}":
            if cur_name is not None:
                comps.append((cur_name, cur_lines))
            cur_name, cur_lines = None, []
            continue
        if cur_name is not None:
            cur_lines.append(s)
    return comps, entry_name


def parse_module(text: str) -> dict[str, CompCost]:
    comps: dict[str, CompCost] = {}
    blocks, entry_name = _split_computations(text)
    for name, lines in blocks:
        cur = comps.setdefault(name, CompCost())
        symtab: dict[str, list[int]] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                symtab[dm.group(1)] = _all_shapes(dm.group(2))[0][1]
        for line in lines:
            if " dot(" in line:
                cur.flops += _dot_flops(line, symtab)
            cm = _CONST_RE.search(line)
            if cm:
                cur.max_const = max(cur.max_const, int(cm.group(1)))
            matched_coll = False
            for kind in COLLECTIVES:
                if f" {kind}(" in line or f"={kind}(" in line:
                    _, b = _shape_numel_bytes(line.split("=", 1)[-1])
                    cur.coll_bytes[kind] = cur.coll_bytes.get(kind, 0) + b
                    matched_coll = True
                    break
            del matched_coll
            if ("=" in line and "[" in line
                    and not any(f in line for f in _FREE_OPS)):
                _, b = _shape_numel_bytes(line.split("=", 1)[-1])
                cur.out_bytes += b
            bm = _BODY_RE.search(line)
            if bm:
                cm2 = _COND_RE.search(line)
                cur.whiles.append((bm.group(1),
                                   cm2.group(1) if cm2 else None))
            for cname in _CALLS_RE.findall(line):
                cur.calls.append(cname)
    comps["__entry__"] = comps.get(entry_name, CompCost())
    return comps


@dataclasses.dataclass
class ModuleCost:
    flops: float
    out_bytes: float
    coll_bytes: dict
    trip_counts: dict


def rollup(comps: dict, root: str = "__entry__") -> ModuleCost:
    trip_counts: dict[str, int] = {}
    memo: dict[str, tuple] = {}

    def visit(name: str, stack=()) -> tuple:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps or name == "__entry_name__":
            return (0.0, 0.0, {})
        c = comps[name]
        if not isinstance(c, CompCost):
            return (0.0, 0.0, {})
        fl, ob = c.flops, c.out_bytes
        cb = dict(c.coll_bytes)
        for callee in c.calls:
            # FLOPs live inside fusions; fusion-internal outputs stay in
            # registers/VMEM and do NOT count as HBM traffic (the fusion
            # op's own output was already counted at this level).
            f2, _b2, c2 = visit(callee, stack + (name,))
            fl += f2
            for k, v in c2.items():
                cb[k] = cb.get(k, 0) + v
        for body, cond in c.whiles:
            trips = 1
            if cond and cond in comps and isinstance(comps[cond], CompCost):
                trips = max(1, comps[cond].max_const)
            trip_counts[body] = trips
            f2, b2, c2 = visit(body, stack + (name,))
            fl += f2 * trips
            ob += b2 * trips
            for k, v in c2.items():
                cb[k] = cb.get(k, 0) + v * trips
        memo[name] = (fl, ob, cb)
        return memo[name]

    fl, ob, cb = visit(root)
    return ModuleCost(flops=fl, out_bytes=ob, coll_bytes=cb,
                      trip_counts=trip_counts)


def analyze(text: str) -> ModuleCost:
    return rollup(parse_module(text))


def effective_collective_bytes(coll_bytes: dict) -> float:
    """Ring-algorithm wire-bytes factors per collective kind."""
    factors = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
    return sum(v * factors.get(k, 1.0) for k, v in coll_bytes.items())
