"""Production mesh construction (spec-mandated API).

Defined as functions — importing this module never touches jax device
state.  Single pod: (16, 16) = 256 chips, axes (data, model).  Multi-pod:
(2, 16, 16) = 512 chips, axes (pod, data, model); the pod axis carries
data parallelism with gradient compression across the slower inter-pod
links (train/compression.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices=None):
    """1x1 mesh over the local device — smoke tests / examples."""
    import numpy as np
    devices = devices if devices is not None else jax.devices()[:1]
    return jax.sharding.Mesh(
        np.array(devices).reshape(1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
