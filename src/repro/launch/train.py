"""Production training launcher.

On a real fleet this runs under `jax.distributed.initialize()` with the
(2, 16, 16) mesh from mesh.py; on this container it runs the same code
path on the 1x1 debug mesh.  Wires together: config registry, sharded
train step, deterministic data shards, atomic checkpointing, heartbeat
monitoring, straggler tracking, and elastic restart.

  PYTHONPATH=src python -m repro.launch.train --arch granite_3_8b \
      --steps 100 --smoke [--multi-pod]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import registry
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.train import checkpoint as ck
from repro.train import data as data_lib
from repro.train import fault_tolerance as ft
from repro.train import train_loop
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        cfg = dataclasses.replace(cfg, vocab=512)
        mesh = make_debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    dcfg = data_lib.DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                               global_batch=args.global_batch, seed=0)
    ds = data_lib.SyntheticLM(dcfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=min(20, args.steps // 5 + 1),
                      total_steps=args.steps)
    scfg = train_loop.StepConfig(
        microbatches=args.microbatches,
        compute_dtype="float32" if args.smoke else "bfloat16",
        remat=not args.smoke,
        grad_compression=args.grad_compression)
    state = train_loop.init_state(jax.random.PRNGKey(0), cfg, opt, scfg)
    base_step = train_loop.make_train_step(cfg, opt, scfg)

    def step(state, batch):
        with shlib.activate(mesh):
            return base_step(state, batch)

    jitted = jax.jit(step)
    monitor = ft.HeartbeatMonitor(["local"], timeout_s=600)
    straggler = ft.StragglerMitigator()

    def on_metrics(s, m):
        monitor.beat("local")
        if s % 10 == 0 or s == args.steps:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e}")

    def timed_step(state, batch):
        t0 = time.perf_counter()
        out = jitted(state, batch)
        if straggler.record(time.perf_counter() - t0):
            print("  (straggler step flagged — would re-dispatch shard)")
        return out

    state, steps, restarts = ft.run_resumable(
        state, timed_step, lambda s: ds.global_batch(s),
        n_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, on_metrics=on_metrics)
    print(f"finished {steps} steps ({restarts} restarts); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
