import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod), constructs ShapeDtypeStruct stand-ins for the train/serve step
inputs (no allocation), jits with explicit in/out shardings from the
logical-axis rules, ``.lower().compile()``s, and records:

  * memory_analysis()        — proves the cell fits per-device HBM,
  * cost_analysis()          — raw XLA FLOPs/bytes (body-once, see below),
  * hlo_analysis.analyze()   — trip-count-corrected FLOPs / output bytes /
                               per-kind collective bytes (§Roofline inputs),
  * wall-clock trace/compile seconds.

Results append incrementally to results/dryrun/<cell>.json so interrupted
sweeps resume.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun [--arch a] [--shape s]
      [--mesh single|multi|both] [--force] [--list]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.distributed import sharding as shlib
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.models import model as M
from repro.train import train_loop
from repro.train.optimizer import AdamWConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

BATCH_AXES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "enc_input": ("batch", None, "embed"),
    "patches": ("batch", None, "embed"),
    "token": ("batch", None),
    "pos": (),
    "enc_memory": ("batch", None, "embed"),
}

ACT_BUDGET_BYTES = 5e9   # per-device residual budget drives microbatching


def pick_microbatches(cfg: ArchConfig, shape: ShapeConfig, dp: int) -> int:
    if shape.kind != "train":
        return 1
    bshard = max(1, shape.global_batch // dp)
    resid_per_seq = cfg.n_layers * shape.seq_len * cfg.d_model * 2  # bf16
    mb = 1
    while (bshard // mb > 1 and bshard % mb == 0
           and (bshard // mb) * resid_per_seq > ACT_BUDGET_BYTES):
        mb *= 2
    while bshard % mb:
        mb //= 2
    return max(1, mb)


def _shardings_for(mesh, shapes_tree, axes_tree, rules=None):
    return jax.tree_util.tree_map(
        lambda sds, ax: shlib.named_sharding(mesh, sds.shape, ax, rules),
        shapes_tree, axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))


def _state_axes(cfg: ArchConfig, step_cfg) -> train_loop.TrainState:
    pax = M.param_axes(cfg)
    from repro.train.optimizer import AdamWState
    ef_ax = pax if step_cfg.grad_compression != "none" else None
    return train_loop.TrainState(
        params=pax,
        opt=AdamWState(step=(), mu=pax, nu=pax),
        ef=ef_ax, step=())


def arch_rules(cfg: ArchConfig, tp: int) -> dict:
    """Per-arch sharding-rule overrides (§Perf iteration 3).

    Architectures whose head counts don't divide the TP axis (yi/arctic/
    llava 56H, whisper 12H) switch attention to context parallelism: shard
    the sequence over 'model' and all-gather KV per layer — this removes
    the per-chunk logit all-reduces that head_dim-TP caused (29 TB/chip on
    yi prefill_32k in the v0 baseline).
    """
    if cfg.n_heads % tp != 0:
        return {"heads": None, "kv_heads": None, "head_dim": None,
                "seq": "model"}
    return {}


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               *, grad_compression: str = "none") -> dict:
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = registry.cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh_chips(mesh)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    rules = arch_rules(cfg, mesh.shape.get("model", 1))
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "chips": chips, "status": "error"}
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)

    specs = registry.input_specs(cfg, shape)
    batch_axes = {k: BATCH_AXES[k] for k in specs}
    batch_sh = _shardings_for(mesh, specs, batch_axes, rules)

    if shape.kind == "train":
        mb = pick_microbatches(cfg, shape, dp)
        step_cfg = train_loop.StepConfig(
            microbatches=mb, compute_dtype="bfloat16", remat=True,
            grad_compression=grad_compression)
        opt_cfg = AdamWConfig()
        state_sds = jax.eval_shape(
            lambda k: train_loop.init_state(k, cfg, opt_cfg, step_cfg), key)
        state_ax = _state_axes(cfg, step_cfg)
        state_sh = _shardings_for(mesh, state_sds, state_ax, rules)
        step = train_loop.make_train_step(cfg, opt_cfg, step_cfg)
        rec["microbatches"] = mb

        def run(state, batch):
            with shlib.activate(mesh, rules):
                return step(state, batch)

        jitted = jax.jit(run, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
        args = (state_sds, specs)
    elif shape.kind == "prefill":
        params_sds = jax.eval_shape(lambda k: M.init_params(k, cfg), key)
        params_sh = _shardings_for(mesh, params_sds, M.param_axes(cfg),
                                   rules)

        def run(params, batch):
            with shlib.activate(mesh, rules):
                p = train_loop.cast_tree(params, jnp.bfloat16)
                extras = {k: v for k, v in batch.items() if k != "tokens"}
                return M.forward(p, cfg, batch["tokens"], extras=extras,
                                 remat=False)

        jitted = jax.jit(run, in_shardings=(params_sh, batch_sh))
        args = (params_sds, specs)
    else:  # decode
        params_sds = jax.eval_shape(lambda k: M.init_params(k, cfg), key)
        params_sh = _shardings_for(mesh, params_sds, M.param_axes(cfg),
                                   rules)
        cache_sds = jax.eval_shape(
            lambda p: M.init_cache(p, cfg, shape.global_batch, shape.seq_len,
                                   kv_dtype=jnp.bfloat16), params_sds)
        cax = M.cache_axes(cfg)
        cache_ax = {k: dict(cax[k]) for k in cache_sds}
        cache_sh = _shardings_for(mesh, cache_sds, cache_ax, rules)
        tok_sh = {k: v for k, v in batch_sh.items()}

        def run(params, cache, batch):
            with shlib.activate(mesh, rules):
                p = train_loop.cast_tree(params, jnp.bfloat16)
                extras = {k: v for k, v in batch.items()
                          if k not in ("token", "pos")}
                return M.decode_step(p, cfg, batch["token"], cache,
                                     batch["pos"], extras=extras)

        jitted = jax.jit(run, in_shardings=(params_sh, cache_sh, tok_sh),
                         out_shardings=(None, cache_sh))
        args = (params_sds, cache_sds, specs)

    lowered = jitted.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
    except Exception as e:  # pragma: no cover
        rec["cost_analysis"] = {"error": str(e)}

    mc = hlo_analysis.analyze(compiled.as_text())
    rec["hlo"] = {
        "flops_per_chip": mc.flops,
        "out_bytes_per_chip": mc.out_bytes,
        "collective_bytes": {k: float(v) for k, v in mc.coll_bytes.items()},
        "collective_bytes_effective":
            hlo_analysis.effective_collective_bytes(mc.coll_bytes),
        "trip_counts": mc.trip_counts,
    }
    rec["seconds"] = {"trace_lower": round(t1 - t0, 2),
                      "compile": round(t2 - t1, 2)}
    rec["status"] = "ok"
    return rec


def cell_path(arch, shape_name, mesh_kind):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape_name}__{mesh_kind}.json")


def run_cell(arch, shape_name, mesh_kind, force=False) -> dict:
    path = cell_path(arch, shape_name, mesh_kind)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        rec = lower_cell(arch, shape_name, mesh_kind)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else registry.ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    if args.list:
        for a in archs:
            for s in shapes:
                ok, why = registry.cell_is_runnable(
                    registry.get_config(a), SHAPES[s])
                print(f"{a:18s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    n_ok = n_err = n_skip = 0
    for a in archs:
        for s in shapes:
            for mk in meshes:
                rec = run_cell(a, s, mk, force=args.force)
                tag = rec["status"]
                if tag == "ok":
                    n_ok += 1
                    h = rec["hlo"]
                    print(f"OK   {a:18s} {s:12s} {mk:6s} "
                          f"flops/chip={h['flops_per_chip']:.3e} "
                          f"coll={h['collective_bytes_effective']:.3e}B "
                          f"peak={rec['memory'].get('peak_bytes_per_device', 0)/1e9:.2f}GB "
                          f"compile={rec['seconds']['compile']:.0f}s")
                elif tag == "skipped":
                    n_skip += 1
                    print(f"SKIP {a:18s} {s:12s} {mk:6s} {rec['reason']}")
                else:
                    n_err += 1
                    print(f"ERR  {a:18s} {s:12s} {mk:6s} "
                          f"{rec.get('error', '?')}")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
