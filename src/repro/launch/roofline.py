"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

  compute term    = FLOPs_per_chip / peak_FLOPs        (197 TFLOP/s bf16)
  memory term     = bytes_per_chip / HBM_bw            (819 GB/s)
  collective term = wire_bytes_per_chip / link_bw      (~50 GB/s/link ICI)

FLOPs/bytes are the trip-count-corrected per-chip numbers from
hlo_analysis (XLA's cost_analysis counts scan bodies once — both raw and
corrected are recorded).  The dominant term is the bottleneck; MODEL_FLOPS
uses 6*N*D (dense) / 6*N_active*D (MoE) and the ratio MODEL/HLO exposes
remat & overhead waste.  Output: markdown table + per-cell JSON, consumed
by EXPERIMENTS.md.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--out file.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import registry
from repro.configs.base import SHAPES

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (effective, per direction)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D convention (D = tokens processed; decode: 1 token/seq)."""
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_params_per_token()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens            # forward only
    tokens = shape.global_batch                    # one new token per seq
    return 2.0 * n_active * tokens


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    h = rec["hlo"]
    flops_chip = h["flops_per_chip"]
    # HBM traffic ≈ top-level op writes + one read of every argument
    # (weights/optimizer state) per step — both per-device quantities.
    arg_bytes = rec.get("memory", {}).get("argument_bytes", 0)
    bytes_chip = h["out_bytes_per_chip"] + arg_bytes
    coll_chip = h["collective_bytes_effective"]
    t_comp = flops_chip / PEAK_FLOPS
    t_mem = bytes_chip / HBM_BW
    t_coll = coll_chip / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    mf_chip = mf / chips
    total = max(t_comp, t_mem, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_total": mf,
        "model_flops_per_chip": mf_chip,
        "hlo_flops_per_chip": flops_chip,
        "useful_flop_ratio": (mf_chip / flops_chip) if flops_chip else 0.0,
        "roofline_fraction": (mf_chip / PEAK_FLOPS) / total if total else 0.0,
        "step_time_bound_s": total,
        "peak_gb": rec.get("memory", {}).get("peak_bytes_per_device", 0)/1e9,
        "raw_cost_analysis": rec.get("cost_analysis", {}),
        "microbatches": rec.get("microbatches"),
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_flop_ratio"] < 0.5:
            return ("compute-bound with low useful-FLOP ratio: cut remat "
                    "recompute / quadratic-mixer overhead")
        return "compute-bound near useful peak: increase arithmetic intensity"
    if d == "memory":
        return ("memory-bound: fuse elementwise chains, cast caches/params "
                "to bf16, raise per-step tokens per weight read")
    return ("collective-bound: reshard to cut all-gathers (FSDP->TP swap), "
            "overlap collectives with compute, compress cross-pod grads")


def load_cells() -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "bound | useful | roofline frac | peak GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2%} | {r['peak_gb']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = load_cells()
    md = to_markdown(rows)
    print(md)
    for r in rows:
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:6s} -> "
              f"{r['dominant']}: {suggestion(r)}")
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
