"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code annotates tensors with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); a rules table maps logical
names to mesh axes.  A mapping is applied only when the dimension size is
divisible by the mesh-axis size — otherwise that dim silently replicates.
This keeps every (arch x mesh) cell compiling out of the box; the §Perf
hillclimb then attacks cells where fallback replication hurts (e.g. head
counts not divisible by the TP axis — see EXPERIMENTS.md).

Use ``activate(mesh, rules)`` as a context manager; ``constrain`` is a no-op
when nothing is active, so all model code runs unmodified on a single CPU.

``search_mesh`` is the serving-search side of this module (DESIGN.md §11):
a one-axis ``"shard"`` mesh that ``core/search.py`` partitions the corpus
across — the first consumer of the device mesh outside the training stack.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def search_mesh(num_shards: int, devices=None) -> Mesh:
    """One-axis ``("shard",)`` mesh for scatter-gather partitioned search.

    Uses the largest device count that divides ``num_shards`` (each mesh
    slot then owns num_shards / size whole shards; shard_map blocks must
    split the stacked shard axis evenly).  On a single device this is a
    1-way mesh — same program, no cross-device traffic — so the sharded
    search path runs everywhere and distributes when devices exist
    (CI forces 4 CPU devices via --xla_force_host_platform_device_count).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    devices = list(devices if devices is not None else jax.devices())
    size = max(s for s in range(1, min(num_shards, len(devices)) + 1)
               if num_shards % s == 0)
    return Mesh(np.asarray(devices[:size]), ("shard",))


def placement_mesh(x, num_shards: int) -> Mesh:
    """Mesh an array is already placed on, else a fresh ``search_mesh``.

    Sharded-search entry points accept arrays that were ``device_put`` onto
    a ``"shard"`` mesh at partition/restore time; reusing that mesh keeps
    dispatch zero-copy.  Arrays with any other placement (fresh numpy,
    single-device jnp) fall back to the default mesh for ``num_shards``.
    """
    sh = getattr(x, "sharding", None)
    if isinstance(sh, NamedSharding) and "shard" in sh.mesh.shape:
        return sh.mesh
    return search_mesh(num_shards)

# logical name -> mesh axis name (or tuple of axes)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),      # data parallel over pod x data
    "seq": None,                   # sequence kept whole by default
    "seq_shard": "model",          # context-parallel sequence axis (opt-in)
    "kv_seq": "data",              # long-context KV cache sharding (B=1)
    "embed": None,                 # activation d_model dim
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "kv_head_dim": None,           # kv projections replicate over TP
    # fallback TP axis: used when head counts don't divide the TP axis
    # (yi/arctic/llava 56H, whisper 12H, GQA kv=8 on a 16-way axis) — every
    # assigned arch has head_dim % 16 == 0, so attention always TP-shards.
    "head_dim": "model",
    "mlp": "model",                # d_ff (column parallel)
    "mlp_in": "data",              # FSDP shard of the d_model dim of weights
    "kv_seq_full": None,           # attention KV must be seq-complete
    "expert": "model",
    "expert_mlp": None,            # grok-style fallback: shard inside expert
    "conv": None,
    "state": None,
    "layers": None,
}

_local = threading.local()


def _state():
    if not hasattr(_local, "ctx"):
        _local.ctx = None
    return _local.ctx


@contextlib.contextmanager
def activate(mesh: Mesh, rules: dict[str, object] | None = None):
    """Enable logical sharding constraints within the block."""
    prev = _state()
    _local.ctx = (mesh, dict(DEFAULT_RULES, **(rules or {})))
    try:
        yield
    finally:
        _local.ctx = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


def spec_for(shape: tuple[int, ...], names: tuple[str | None, ...],
             mesh: Mesh, rules: dict) -> P:
    """PartitionSpec for logical names; replicate non-divisible dims."""
    assert len(shape) == len(names), (shape, names)
    out = []
    used: set = set()
    for dim, name in zip(shape, names):
        axis = rules.get(name) if name else None
        if axis is None:
            out.append(None)
            continue
        axes = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
        # drop axes absent from this mesh (e.g. 'pod' on the single-pod mesh)
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes or any(a in used for a in axes):
            out.append(None)
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size > 1 and dim % size == 0:
            out.append(axes[0] if len(axes) == 1 else axes)
            used.update(axes)
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *names: str | None) -> jax.Array:
    """with_sharding_constraint by logical names (no-op when inactive)."""
    ctx = _state()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(x.shape, names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, shape: tuple[int, ...],
                   names: tuple[str | None, ...],
                   rules: dict | None = None) -> NamedSharding:
    rules = dict(DEFAULT_RULES, **(rules or {}))
    return NamedSharding(mesh, spec_for(shape, names, mesh, rules))


def tree_shardings(mesh: Mesh, tree_shapes, tree_names, rules=None):
    """Map (shape pytree, logical-name pytree) -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda sh, nm: named_sharding(mesh, sh, nm, rules),
        tree_shapes, tree_names,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (int,)) for e in x))
