"""Core transformer layers: norms, RoPE, GQA attention, MLPs, embeddings.

Pure-JAX parameter pytrees (nested dicts).  Every init_* has a matching
*_axes sibling returning the logical-axis names used by the sharding rules
(tests assert the trees stay congruent).  Computation uses bf16-friendly
patterns with f32 accumulation where it matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops


def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else (1.0 / max(fan_in, 1)) ** 0.5
    return jax.random.normal(key, shape, dtype) * scale


# ----------------------------------------------------------------- norms ---
def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_axes():
    return {"scale": ("embed",)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"] if p.get("_gemma", False) else p["scale"])
            ).astype(x.dtype)


# ------------------------------------------------------------------ rope ---
def rope(x: jax.Array, pos: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, dh); pos: (B, S) absolute positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs       # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------------- attention ---
def init_attention(key, d, n_heads, n_kv, d_head, cross=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, n_heads, d_head)),
        "wk": _init(ks[1], (d, n_kv, d_head)),
        "wv": _init(ks[2], (d, n_kv, d_head)),
        "wo": _init(ks[3], (n_heads, d_head, d), scale=(1.0 / (n_heads * d_head)) ** 0.5),
    }
    return p


def attention_axes():
    # kv projections REPLICATE over TP ("kv_head_dim" -> None): GQA kv-head
    # counts (8, 4, 12) don't divide the 16-way TP axis, and letting the
    # head_dim fallback shard them makes the attention einsum contract over
    # a sharded dim -> f32 logit all-reduces inside the flash region for
    # every GQA arch (EXPERIMENTS.md §Perf iteration 8).  kv weights are
    # tiny (granite: 8 MB bf16), so replication is free.
    return {
        "wq": ("mlp_in", "heads", "head_dim"),
        "wk": ("mlp_in", "kv_heads", "kv_head_dim"),
        "wv": ("mlp_in", "kv_heads", "kv_head_dim"),
        "wo": ("heads", "head_dim", "mlp_in"),
    }


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def attention_train(p, x, *, n_heads, n_kv, d_head, causal=True, window=0,
                    softcap=0.0, rope_theta=1e4, pos0=0, memory=None):
    """Full-sequence attention (train / prefill).

    memory: optional (B, S_kv, d) encoder output for cross-attention
    (whisper decoder); cross-attention is non-causal over memory.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = memory if memory is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if memory is None:
        pos = pos0 + jnp.arange(s)[None, :]
        q = rope(q, jnp.broadcast_to(pos, (b, s)), rope_theta)
        kpos = jnp.arange(k.shape[1])[None, :]
        k = rope(k, jnp.broadcast_to(kpos, (b, k.shape[1])), rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = _repeat_kv(k, n_heads)
    v = _repeat_kv(v, n_heads)
    # keep KV seq-complete: under context-parallel sharding (seq -> model)
    # this is the per-layer KV all-gather; under head-TP it is a no-op
    k = constrain(k, "batch", "kv_seq_full", "heads", "head_dim")
    v = constrain(v, "batch", "kv_seq_full", "heads", "head_dim")
    out = ops.flash_attention(
        jnp.transpose(q, (0, 2, 1, 3)), jnp.transpose(k, (0, 2, 1, 3)),
        jnp.transpose(v, (0, 2, 1, 3)),
        causal=causal and memory is None, window=window, softcap=softcap,
        q_offset=pos0)
    out = jnp.transpose(out, (0, 2, 1, 3))                  # (B, S, H, dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(p, x1, cache_k, cache_v, pos, *, n_heads, n_kv, d_head,
                     window=0, softcap=0.0, rope_theta=1e4, memory=None):
    """One-token decode against a KV cache.

    x1: (B, 1, d); cache_k/v: (B, S_max, n_kv, dh); pos: () current index.
    Returns (y (B, 1, d), cache_k, cache_v).
    """
    b = x1.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x1, p["wq"])
    if memory is None:
        posb = jnp.broadcast_to(pos[None, None], (b, 1))
        q = rope(q, posb, rope_theta)
        k1 = jnp.einsum("bsd,dhk->bshk", x1, p["wk"])
        v1 = jnp.einsum("bsd,dhk->bshk", x1, p["wv"])
        k1 = rope(k1, posb, rope_theta)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k1.astype(cache_k.dtype), pos, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v1.astype(cache_v.dtype), pos, 1)
        keys, vals = cache_k, cache_v
        s_kv = keys.shape[1]
        kpos = jnp.arange(s_kv)
        mask = kpos <= pos
        if window > 0:
            mask &= kpos > pos - window
    else:
        keys = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
        vals = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
        mask = jnp.ones((keys.shape[1],), bool)
    keys = constrain(keys, "batch", "kv_seq", "kv_heads", "head_dim")
    vals = constrain(vals, "batch", "kv_seq", "kv_heads", "head_dim")
    kk = _repeat_kv(keys, n_heads)
    vv = _repeat_kv(vals, n_heads)
    logits = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / (d_head ** 0.5)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", w, vv.astype(jnp.float32))
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x1.dtype), p["wo"])
    return y, cache_k, cache_v


# -------------------------------------------------------------------- mlp ---
def init_mlp(key, d, d_ff, act="swiglu"):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"wi": _init(ks[0], (d, d_ff)), "wg": _init(ks[1], (d, d_ff)),
                "wo": _init(ks[2], (d_ff, d))}
    return {"wi": _init(ks[0], (d, d_ff)), "wo": _init(ks[2], (d_ff, d))}


def mlp_axes(act="swiglu"):
    ax = {"wi": ("mlp_in", "mlp"), "wo": ("mlp", "mlp_in")}
    if act == "swiglu":
        ax["wg"] = ("mlp_in", "mlp")
    return ax


def mlp(p, x, act="swiglu"):
    h = x @ p["wi"]
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(h)
    names = ("batch", "seq", "mlp") if h.ndim == 3 else ("batch", "mlp")
    h = constrain(h, *names)
    return h @ p["wo"]


# ------------------------------------------------------------- embedding ---
def init_embed(key, vocab, d, tie=True):
    p = {"emb": _init(key, (vocab, d), scale=1.0)}
    if not tie:
        p["head"] = _init(jax.random.fold_in(key, 1), (d, vocab))
    return p


def embed_axes(tie=True):
    ax = {"emb": ("vocab", "embed")}
    if not tie:
        ax["head"] = ("embed", "vocab")
    return ax


def embed(p, tokens):
    return constrain(p["emb"][tokens], "batch", "seq", "embed")


def unembed(p, x, softcap=0.0):
    w = p.get("head")
    logits = x @ w if w is not None else x @ p["emb"].T
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return constrain(logits, "batch", "seq", "vocab")
