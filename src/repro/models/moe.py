"""Mixture-of-Experts FFN with sort-based (linear-FLOPs) dispatch.

Top-k routing with a static per-expert capacity C = ceil(T*k/E * cf):
token->expert assignments are grouped by a sort + run-rank (no atomics,
static shapes — the same grouping primitive as core/commit.py), dispatched
with one scatter, processed as a single (E, C, d) x (E, d, f) batched MXU
contraction, and combined with a weighted scatter-add.  Overflow beyond
capacity is dropped (standard GShard/MaxText behaviour).

Sharding: the expert axis maps to the TP mesh axis when divisible
(arctic 128e, jamba 16e); otherwise experts replicate and each expert's d_ff
shards (grok 8e over a 16-way axis) — see distributed/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import _init


def init_moe(key, d, d_ff, n_experts, act="swiglu"):
    ks = jax.random.split(key, 4)
    p = {
        "router": _init(ks[0], (d, n_experts)),
        "wi": _init(ks[1], (n_experts, d, d_ff)),
        "wo": _init(ks[3], (n_experts, d_ff, d)),
    }
    if act == "swiglu":
        p["wg"] = _init(ks[2], (n_experts, d, d_ff))
    return p


def moe_axes(act="swiglu"):
    ax = {
        "router": ("embed", None),
        "wi": ("expert", "mlp_in", "mlp"),
        "wo": ("expert", "mlp", "mlp_in"),
    }
    if act == "swiglu":
        ax["wg"] = ("expert", "mlp_in", "mlp")
    return ax


def _group_ranks(sorted_ids: jax.Array) -> jax.Array:
    e = sorted_ids.shape[0]
    idx = jnp.arange(e)
    is_start = jnp.concatenate(
        [jnp.array([True]), sorted_ids[1:] != sorted_ids[:-1]])
    start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    return idx - start


def moe_ffn(p, x, *, n_experts, top_k=2, capacity_factor=1.25,
            act="swiglu"):
    """x: (T, d) flattened tokens -> (T, d)."""
    t, d = x.shape
    e = n_experts
    cap = max(1, int(capacity_factor * t * top_k / e))
    cap = -(-cap // 8) * 8                                  # bucketed

    gates = x @ p["router"]                                 # (T, E)
    top_vals, top_idx = jax.lax.top_k(gates, top_k)         # (T, k)
    probs = jax.nn.softmax(top_vals.astype(jnp.float32), axis=-1)

    flat_e = top_idx.reshape(-1)                            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_p = probs.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    rank = _group_ranks(se)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)        # drop overflow

    # Gather-based dispatch/combine: scattering (T*k, d) token rows into an
    # EXPERT-SHARDED buffer lowers to SPMD's replicate-and-reduce fallback
    # (a u32[T*k, d] all-reduce — 2.1 TB/chip on arctic train_4k v1).  Only
    # tiny int32 INDEX tables are scattered; the wide data movement is two
    # gathers the partitioner turns into all-to-all-class traffic.  When the
    # expert dim replicates (grok: 8e < 16-way TP) the scatter is local and
    # cheaper — keep it there (EXPERIMENTS.md §Perf iterations 6-7).
    if n_experts >= 16:          # expert axis shards on the production mesh
        slot_to_tok = jnp.full((e * cap,), t, jnp.int32).at[slot].set(
            st.astype(jnp.int32), mode="drop")              # (E*C,) int32
        xin = jnp.where((slot_to_tok < t)[:, None],
                        x[jnp.minimum(slot_to_tok, t - 1)], 0.0)
        xin = xin.reshape(e, cap, d)
    else:
        xin = jnp.zeros((e * cap, d), x.dtype).at[slot].set(
            x[st], mode="drop").reshape(e, cap, d)
    # capacity dim shards over the DP axes so per-chip expert FLOPs scale
    # with the fleet (EXPERIMENTS.md §Perf iteration 2)
    xin = constrain(xin, "expert", "batch", "embed")
    h = jnp.einsum("ecd,edf->ecf", xin, p["wi"])
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xin, p["wg"])
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "expert", "batch", "mlp")
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(e * cap, d)

    # combine: token t reads back its k slots (gather in flat token order —
    # the inverse of the dispatch sort), weighted by gate probs
    inv = jnp.argsort(order)
    slot_by_flat = jnp.where(keep, slot, e * cap)[inv]      # (T*k,) flat order
    y_pad = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = y_pad[slot_by_flat].reshape(t, top_k, d)
    w = probs.astype(x.dtype).reshape(t, top_k, 1)
    out = jnp.sum(contrib * w, axis=1)
    return constrain(out, "batch", "embed")


def aux_load_balance_loss(p, x, *, n_experts, top_k=2):
    """Switch-style load-balance auxiliary loss (mean fraction * mean prob)."""
    gates = jax.nn.softmax((x @ p["router"]).astype(jnp.float32), axis=-1)
    _, top_idx = jax.lax.top_k(gates, top_k)
    onehot = jax.nn.one_hot(top_idx, n_experts).sum(axis=1)  # (T, E)
    frac = jnp.mean(onehot, axis=0)
    prob = jnp.mean(gates, axis=0)
    return n_experts * jnp.sum(frac * prob)
