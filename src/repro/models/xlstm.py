"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, exponential gating, sequential scan).

mLSTM trains with the chunkwise linear-attention formulation (intra-chunk
quadratic on W=128 windows + carried (dk, dv) state — O(S) FLOPs, constant
memory per chunk) and decodes with the exact per-step recurrence including
the paper's stabilizer.  Training-path input gates are clipped instead of
carrying the running-max stabilizer across chunks (DESIGN.md §8 notes the
simplification; decode is exact).

sLSTM keeps per-cell states (c, n, m) with block-diagonal per-head
recurrent weights and runs as a lax.scan — inherently sequential, exactly
like the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import _init, init_rmsnorm, rmsnorm

CHUNK = 128


# ------------------------------------------------------------------ mLSTM ---
def init_mlstm(key, d, n_heads, *, expand=2):
    di = expand * d
    dh = di // n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": _init(ks[0], (d, 2 * di)),
        "wq": _init(ks[1], (di, n_heads, dh)),
        "wk": _init(ks[2], (di, n_heads, dh)),
        "wv": _init(ks[3], (di, n_heads, dh)),
        "wi": _init(ks[4], (di, n_heads)),
        "wf": _init(ks[5], (di, n_heads)),
        "fb": jnp.full((n_heads,), 3.0),      # forget-gate bias (open)
        "down": _init(ks[6], (di, d)),
    }


def mlstm_axes():
    return {"up": ("mlp_in", "mlp"), "wq": ("mlp", "heads", "head_dim"),
            "wk": ("mlp", "heads", "head_dim"),
            "wv": ("mlp", "heads", "head_dim"),
            "wi": ("mlp", "heads"), "wf": ("mlp", "heads"), "fb": ("heads",),
            "down": ("mlp", "mlp_in")}


def _mlstm_gates(p, xi):
    logi = jnp.clip(xi @ p["wi"], -10.0, 10.0)              # (..., H)
    logf = jax.nn.log_sigmoid(xi @ p["wf"] + p["fb"])
    return logi, logf


def mlstm_forward(p, x):
    """x: (B, S, d) -> (B, S, d); tail-pads S to a chunk multiple."""
    b, s, d = x.shape
    di = p["down"].shape[0]
    h2 = x @ p["up"]
    xi, z = h2[..., :di], h2[..., di:]
    xi = constrain(xi, "batch", "seq", "mlp")
    q = jnp.einsum("bsd,dhk->bshk", xi, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xi, p["wk"]) / (q.shape[-1] ** 0.5)
    v = jnp.einsum("bsd,dhk->bshk", xi, p["wv"])
    logi, logf = _mlstm_gates(p, xi)                        # (B,S,H)

    chunk = min(CHUNK, s)
    s_pad = -(-s // chunk) * chunk
    nw = s_pad // chunk

    def rs(t):
        if s_pad != s:
            pad = [(0, 0), (0, s_pad - s)] + [(0, 0)] * (t.ndim - 2)
            t = jnp.pad(t, pad)
        return jnp.moveaxis(t.reshape(b, nw, chunk, *t.shape[2:]), 1, 0)

    qc, kc, vc, ic, fc = map(rs, (q, k, v, logi, logf))

    def step(carry, inp):
        cmat, nvec = carry                                  # (B,H,dk,dv),(B,H,dk)
        qw, kw, vw, iw, fw = inp
        w = qw.shape[1]
        lf = jnp.cumsum(fw, axis=1)                         # (B,W,H)
        # intra-chunk: scores[t,s] = exp(lf_t - lf_s + i_s), s <= t
        gap = lf[:, :, None, :] - lf[:, None, :, :] + iw[:, None, :, :]
        wmask = jnp.tril(jnp.ones((w, w), bool))
        sc = jnp.where(wmask[None, :, :, None], jnp.exp(gap), 0.0)
        qk = jnp.einsum("bthk,bshk->btsh", qw, kw)          # (B,W,W,H)
        intra = jnp.einsum("btsh,btsh,bshv->bthv", qk, sc, vw)
        nintra = jnp.einsum("btsh,bshk->bthk", sc, kw)      # normalizer keys
        # inter-chunk from carried state
        dec = jnp.exp(lf)                                   # (B,W,H)
        inter = jnp.einsum("bthk,bhkv,bth->bthv", qw, cmat, dec)
        ninter = jnp.einsum("bthk,bhk,bth->bth", qw, nvec, dec)
        hnum = intra + inter                                # (B,W,H,dv)
        nden = jnp.einsum("bthk,bthk->bth", qw, nintra) + ninter
        hout = hnum / jnp.maximum(jnp.abs(nden), 1.0)[..., None]
        # carry update
        tot = lf[:, -1]                                     # (B,H)
        wk_dec = jnp.exp(tot[:, None, :] - lf + iw)         # (B,W,H)
        cnew = (cmat * jnp.exp(tot)[..., None, None]
                + jnp.einsum("bshk,bsh,bshv->bhkv", kw, wk_dec, vw))
        nnew = (nvec * jnp.exp(tot)[..., None]
                + jnp.einsum("bshk,bsh->bhk", kw, wk_dec))
        return (cnew, nnew), hout

    nh, dh = q.shape[2], q.shape[3]
    carry0 = (jnp.zeros((b, nh, dh, dh), jnp.float32),
              jnp.zeros((b, nh, dh), jnp.float32))
    _, hs = jax.lax.scan(step, carry0, (qc, kc, vc, ic, fc))
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, s_pad, di)[:, :s]
    return (hout * jax.nn.silu(z)) @ p["down"]


def init_mlstm_cache(p, batch):
    nh = p["wq"].shape[1]
    dh = p["wq"].shape[2]
    return {"c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def mlstm_decode_step(p, x1, cache):
    """Exact stabilized recurrence, one token.  x1: (B, 1, d)."""
    b = x1.shape[0]
    di = p["down"].shape[0]
    h2 = x1[:, 0] @ p["up"]
    xi, z = h2[..., :di], h2[..., di:]
    q = jnp.einsum("bd,dhk->bhk", xi, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", xi, p["wk"]) / (q.shape[-1] ** 0.5)
    v = jnp.einsum("bd,dhk->bhk", xi, p["wv"])
    logi = jnp.clip(xi @ p["wi"], -10.0, 10.0)
    logf = jax.nn.log_sigmoid(xi @ p["wf"] + p["fb"])
    m_new = jnp.maximum(logf + cache["m"], logi)            # stabilizer
    i = jnp.exp(logi - m_new)
    f = jnp.exp(logf + cache["m"] - m_new)
    c = f[..., None, None] * cache["c"] + i[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f[..., None] * cache["n"] + i[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, c)
    # stabilized form: true values carry exp(m); the |.|>=1 floor therefore
    # rescales to exp(-m) in stabilized coordinates (xLSTM eq. 15)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new))
    hout = (num / den[..., None]).reshape(b, di)
    y = (hout * jax.nn.silu(z)) @ p["down"]
    return y[:, None], {"c": c, "n": n, "m": m_new}


# ------------------------------------------------------------------ sLSTM ---
def init_slstm(key, d, n_heads):
    dh = d // n_heads
    ks = jax.random.split(key, 10)
    p = {"w": _init(ks[0], (d, 4 * d)),                    # z,i,f,o inputs
         "r": _init(ks[1], (4, n_heads, dh, dh), scale=0.3 / dh ** 0.5),
         "b": jnp.zeros((4 * d,)).at[2 * d:3 * d].set(2.0),  # forget open
         "down": _init(ks[2], (d, d))}
    return p


def slstm_axes():
    return {"w": ("mlp_in", "mlp"), "r": (None, "heads", None, "head_dim"),
            "b": ("mlp",), "down": ("mlp_in", "mlp_in")}


def slstm_forward(p, x):
    """x: (B, S, d) -> (B, S, d); sequential scan (inherently recurrent)."""
    b, s, d = x.shape
    nh = p["r"].shape[1]
    dh = d // nh
    pre = x @ p["w"] + p["b"]                               # (B,S,4d)

    def step(carry, pre_t):
        c, n, m, h = carry                                  # (B,nh,dh) each
        rec = jnp.einsum("bhk,ghkl->bghl", h, p["r"])       # (B,4,nh,dh)
        g = pre_t.reshape(b, 4, nh, dh) + rec
        zt = jnp.tanh(g[:, 0])
        it = g[:, 1]
        ft = g[:, 2]
        ot = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + m, it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(jax.nn.log_sigmoid(ft) + m - m_new)
        c_new = f * c + i * zt
        n_new = f * n + i
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    z0 = jnp.zeros((b, nh, dh), jnp.float32)
    carry0 = (z0, z0, jnp.full((b, nh, dh), -1e30), z0)
    _, hs = jax.lax.scan(step, carry0, jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    return h @ p["down"]


def init_slstm_cache(p, batch):
    nh = p["r"].shape[1]
    dh = p["r"].shape[2]
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, nh, dh), -1e30), "h": z}


def slstm_decode_step(p, x1, cache):
    b, _, d = x1.shape
    nh = p["r"].shape[1]
    dh = d // nh
    pre = (x1[:, 0] @ p["w"] + p["b"]).reshape(b, 4, nh, dh)
    rec = jnp.einsum("bhk,ghkl->bghl", cache["h"], p["r"])
    g = pre + rec
    zt, it, ft, ot = (jnp.tanh(g[:, 0]), g[:, 1], g[:, 2],
                      jax.nn.sigmoid(g[:, 3]))
    m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + cache["m"], it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(jax.nn.log_sigmoid(ft) + cache["m"] - m_new)
    c = f * cache["c"] + i * zt
    n = f * cache["n"] + i
    h = ot * c / jnp.maximum(n, 1.0)
    y = h.reshape(b, d) @ p["down"]
    return y[:, None], {"c": c, "n": n, "m": m_new, "h": h}
