"""Mamba selective-SSM block (jamba's recurrent layer).

Chunked selective scan: the sequence is split into chunks; within a chunk
the linear recurrence h_t = Abar_t h_{t-1} + Bbar_t x_t runs as an
associative scan (parallel prefix, TPU-friendly), and a lax.scan carries the
(B, d_inner, d_state) state across chunks — O(S) FLOPs, chunk-bounded
memory.  Decode is the exact single-step recurrence with a carried state
and a rolling conv window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import _init

CONV_K = 4
CHUNK = 128


def init_mamba(key, d, *, expand=2, d_state=16, dt_rank=None):
    di = expand * d
    dt_rank = dt_rank or max(1, d // 16)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": _init(ks[0], (d, 2 * di)),
        "conv_w": _init(ks[1], (CONV_K, di), scale=0.5),
        "conv_b": jnp.zeros((di,)),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * d_state)),
        "dt_proj": _init(ks[3], (dt_rank, di)),
        "dt_bias": jnp.full((di,), -4.6),                 # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1., d_state + 1.), (di, 1))),
        "D": jnp.ones((di,)),
        "out_proj": _init(ks[4], (di, d)),
    }


def mamba_axes():
    return {
        "in_proj": ("mlp_in", "mlp"), "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",), "x_proj": ("mlp", None), "dt_proj": (None, "mlp"),
        "dt_bias": ("mlp",), "A_log": ("mlp", "state"), "D": ("mlp",),
        "out_proj": ("mlp", "mlp_in"),
    }


def _ssm_inputs(p, xc, d_state):
    """Common discretization: returns (abar, bx, c) for scan steps."""
    dt_rank = p["dt_proj"].shape[0]
    xdb = xc @ p["x_proj"]                                  # (..., r+2s)
    dt = jax.nn.softplus(xdb[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    bmat = xdb[..., dt_rank:dt_rank + d_state]              # (..., s)
    cmat = xdb[..., dt_rank + d_state:]                     # (..., s)
    a = -jnp.exp(p["A_log"])                                # (di, s)
    abar = jnp.exp(dt[..., None] * a)                       # (..., di, s)
    bx = (dt * xc)[..., None] * bmat[..., None, :]          # (..., di, s)
    return abar, bx, cmat


def _chunk_scan(carry, abar, bx):
    """Associative scan within a chunk given incoming state ``carry``."""
    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    a_acc, h = jax.lax.associative_scan(op, (abar, bx), axis=1)
    h = h + a_acc * carry[:, None]                          # inject carry
    return h, h[:, -1]


def mamba_forward(p, x, *, d_state=16):
    """x: (B, S, d) -> (B, S, d).  Tail-pads S to a chunk multiple."""
    b, s, d = x.shape
    di = p["in_proj"].shape[1] // 2
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xin = constrain(xin, "batch", "seq", "mlp")

    # causal depthwise conv (width CONV_K)
    pad = jnp.pad(xin, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    xc = sum(pad[:, i:i + s] * p["conv_w"][i] for i in range(CONV_K))
    xc = jax.nn.silu(xc + p["conv_b"])

    chunk = min(CHUNK, s)
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        xc = jnp.pad(xc, ((0, 0), (0, s_pad - s), (0, 0)))
    nchunk = s_pad // chunk
    xcc = xc.reshape(b, nchunk, chunk, di)

    def step(carry, xck):
        abar, bx, cmat = _ssm_inputs(p, xck, d_state)       # (B,W,di,s)
        h, new_carry = _chunk_scan(carry, abar, bx)
        y = jnp.einsum("bwds,bws->bwd", h, cmat)
        return new_carry, y

    carry0 = jnp.zeros((b, di, d_state), x.dtype)
    _, ys = jax.lax.scan(step, carry0, jnp.moveaxis(xcc, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, di)[:, :s]
    y = y + xc[:, :s] * p["D"]
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def init_mamba_cache(p, batch):
    di = p["in_proj"].shape[1] // 2
    d_state = p["A_log"].shape[1]
    return {
        "h": jnp.zeros((batch, di, d_state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, di), jnp.float32),
    }


def mamba_decode_step(p, x1, cache, *, d_state=16):
    """x1: (B, 1, d); exact single-step recurrence."""
    b = x1.shape[0]
    di = p["in_proj"].shape[1] // 2
    xz = x1[:, 0] @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([cache["conv"], xin[:, None]], axis=1)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"])
    xc = jax.nn.silu(xc + p["conv_b"])
    abar, bx, cmat = _ssm_inputs(p, xc, d_state)            # (B,di,s)
    h = abar * cache["h"] + bx
    y = jnp.einsum("bds,bs->bd", h, cmat) + xc * p["D"]
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": h, "conv": window[:, 1:]}
