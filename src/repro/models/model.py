"""Model assembly: config -> init / train-forward / prefill / decode.

Layers stack as ``lax.scan`` over *period groups*: the repeating pattern
(gemma local:global, jamba attn:mamba 1:7, xlstm mLSTM:sLSTM 7:1, MoE
interleave) defines a group of heterogeneous sublayers; groups are
homogeneous so parameters stack to (n_groups, ...) leaves and the HLO stays
layer-count-independent (compile time and cost-analysis sanity at 60-layer
scale).  ``jax.checkpoint`` wraps the group body (remat).

The uniform API (used by train/serve/launch):
  init(key) -> params
  forward(params, batch) -> logits            # train/prefill path
  init_cache(batch_size, max_seq) -> cache
  prefill(params, tokens, cache, extras) -> (logits, cache)
  decode_step(params, token, cache, pos, extras) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str          # 'attn' | 'mamba' | 'mlstm' | 'slstm'
    window: int = 0     # 0 = global attention
    moe: bool = False
    mlp: bool = True    # has an FFN sublayer (False for xlstm blocks)
    cross: bool = False


def layer_plan(cfg: ArchConfig) -> list[LayerKind]:
    """The repeating pattern of one period group."""
    plan = []
    for j in range(cfg.period):
        # mixer choice
        if cfg.ssm == "xlstm":
            mixer = ("slstm" if cfg.slstm_period and
                     (j % cfg.slstm_period == cfg.slstm_period - 1)
                     else "mlstm")
        elif cfg.ssm == "mamba":
            is_attn = cfg.attn_period and (
                j % cfg.attn_period == cfg.attn_period // 2)
            mixer = "attn" if is_attn else "mamba"
        else:
            mixer = "attn"
        # local/global window pattern (gemma: global every p-th layer)
        window = 0
        if cfg.local_global_period and mixer == "attn":
            if j % cfg.local_global_period != cfg.local_global_period - 1:
                window = cfg.window
        elif cfg.window and not cfg.local_global_period:
            window = cfg.window
        moe = bool(cfg.n_experts) and (j % cfg.moe_period
                                       == cfg.moe_period - 1)
        mlp = cfg.d_ff > 0 and not (mixer in ("mlstm",))
        plan.append(LayerKind(mixer=mixer, window=window, moe=moe, mlp=mlp,
                              cross=cfg.is_encdec))
    return plan


# --------------------------------------------------------------- init -------
def _init_sublayer(key, cfg: ArchConfig, kind: LayerKind) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"ln1": L.init_rmsnorm(d)}
    if kind.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim)
    elif kind.mixer == "mamba":
        p["mamba"] = mamba_lib.init_mamba(ks[0], d, d_state=cfg.d_state)
    elif kind.mixer == "mlstm":
        p["mlstm"] = xlstm_lib.init_mlstm(ks[0], d, cfg.n_heads)
    elif kind.mixer == "slstm":
        p["slstm"] = xlstm_lib.init_slstm(ks[0], d, cfg.n_heads)
    if kind.cross:
        p["ln_x"] = L.init_rmsnorm(d)
        p["cross"] = L.init_attention(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim, cross=True)
    if kind.moe:
        p["ln2"] = L.init_rmsnorm(d)
        p["moe"] = moe_lib.init_moe(ks[2], d, cfg.d_ff, cfg.n_experts,
                                    cfg.act)
        if cfg.dense_residual:
            p["dense_mlp"] = L.init_mlp(ks[3], d, cfg.d_ff, cfg.act)
    elif kind.mlp:
        p["ln2"] = L.init_rmsnorm(d)
        p["mlp"] = L.init_mlp(ks[2], d, cfg.d_ff, cfg.act)
    elif kind.mixer == "slstm":
        p["ln2"] = L.init_rmsnorm(d)
        p["mlp"] = L.init_mlp(ks[2], d, max(1, 4 * d // 3), cfg.act)
    return p


def init_params(key, cfg: ArchConfig):
    plan = layer_plan(cfg)
    kb, ke, kh, kenc = jax.random.split(key, 4)
    group_keys = jax.random.split(kb, cfg.n_groups)

    def one_group(k):
        sub_keys = jax.random.split(k, len(plan))
        return {f"sub{j}": _init_sublayer(sub_keys[j], cfg, plan[j])
                for j in range(len(plan))}

    blocks = jax.vmap(one_group)(group_keys)    # stacked (n_groups, ...)
    params = {
        "embed": L.init_embed(ke, cfg.vocab, cfg.d_model,
                              tie=cfg.tie_embeddings),
        "blocks": blocks,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.is_encdec:
        enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
        enc_kind = LayerKind(mixer="attn")

        def one_enc(k):
            return _init_sublayer(k, cfg, enc_kind)
        params["encoder"] = jax.vmap(one_enc)(enc_keys)
        params["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    return params


# ---------------------------------------------------------- logical axes ----
def _sublayer_axes(cfg: ArchConfig, kind: LayerKind) -> dict:
    ax: dict[str, Any] = {"ln1": L.rmsnorm_axes()}
    if kind.mixer == "attn":
        ax["attn"] = L.attention_axes()
    elif kind.mixer == "mamba":
        ax["mamba"] = mamba_lib.mamba_axes()
    elif kind.mixer == "mlstm":
        ax["mlstm"] = xlstm_lib.mlstm_axes()
    elif kind.mixer == "slstm":
        ax["slstm"] = xlstm_lib.slstm_axes()
    if kind.cross:
        ax["ln_x"] = L.rmsnorm_axes()
        ax["cross"] = L.attention_axes()
    if kind.moe:
        ax["ln2"] = L.rmsnorm_axes()
        ax["moe"] = moe_lib.moe_axes(cfg.act)
        if cfg.dense_residual:
            ax["dense_mlp"] = L.mlp_axes(cfg.act)
    elif kind.mlp or kind.mixer == "slstm":
        ax["ln2"] = L.rmsnorm_axes()
        ax["mlp"] = L.mlp_axes(cfg.act)
    return ax


def _stack_axes(tree):
    return jax.tree_util.tree_map(
        lambda t: ("layers", *t), tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))


def param_axes(cfg: ArchConfig) -> dict:
    """Logical-axis names mirroring init_params' structure exactly."""
    plan = layer_plan(cfg)
    blocks = {f"sub{j}": _sublayer_axes(cfg, plan[j])
              for j in range(len(plan))}
    axes = {
        "embed": L.embed_axes(tie=cfg.tie_embeddings),
        "blocks": _stack_axes(blocks),
        "final_norm": L.rmsnorm_axes(),
    }
    if cfg.is_encdec:
        enc = _sublayer_axes(cfg, LayerKind(mixer="attn"))
        axes["encoder"] = _stack_axes(enc)
        axes["enc_norm"] = L.rmsnorm_axes()
    return axes


def cache_axes(cfg: ArchConfig) -> dict:
    plan = layer_plan(cfg)
    c = {}
    for j, kind in enumerate(plan):
        if kind.mixer == "attn":
            kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
            c[f"sub{j}"] = {"k": kv, "v": kv}
        elif kind.mixer == "mamba":
            c[f"sub{j}"] = {"h": ("layers", "batch", "mlp", "state"),
                            "conv": ("layers", "batch", "conv", "mlp")}
        elif kind.mixer == "mlstm":
            c[f"sub{j}"] = {"c": ("layers", "batch", "heads", None, None),
                            "n": ("layers", "batch", "heads", "head_dim"),
                            "m": ("layers", "batch", "heads")}
        else:
            ax = ("layers", "batch", "heads", "head_dim")
            c[f"sub{j}"] = {"c": ax, "n": ax, "m": ax, "h": ax}
    return c


# ------------------------------------------------------------ sublayer ------
def _apply_sublayer(p, x, cfg: ArchConfig, kind: LayerKind, *,
                    memory=None, pos0=0):
    d = cfg.d_model
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.mixer == "attn":
        mix = L.attention_train(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            d_head=cfg.head_dim, causal=True, window=kind.window,
            softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta, pos0=pos0)
        mix = checkpoint_name(mix, "tp_reduced")   # saved under remat policy
    elif kind.mixer == "mamba":
        mix = mamba_lib.mamba_forward(p["mamba"], h, d_state=cfg.d_state)
    elif kind.mixer == "mlstm":
        mix = xlstm_lib.mlstm_forward(p["mlstm"], h)
    else:
        mix = xlstm_lib.slstm_forward(p["slstm"], h)
    x = x + mix.astype(x.dtype)
    if kind.cross and memory is not None:
        h = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + L.attention_train(
            p["cross"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            d_head=cfg.head_dim, causal=False,
            memory=memory).astype(x.dtype)
    if kind.moe:
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        t = h.reshape(-1, d)
        y = moe_lib.moe_ffn(p["moe"], t, n_experts=cfg.n_experts,
                            top_k=cfg.experts_per_tok, act=cfg.act,
                            capacity_factor=cfg.moe_capacity_factor)
        if cfg.dense_residual:
            y = y + L.mlp(p["dense_mlp"], t, cfg.act)
        x = x + y.reshape(x.shape).astype(x.dtype)
    elif "mlp" in p:
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        y = checkpoint_name(L.mlp(p["mlp"], h, cfg.act), "tp_reduced")
        x = x + y.astype(x.dtype)
    return constrain(x, "batch", "seq", "embed")


# ---------------------------------------------------------------- forward ---
def _encode(params, cfg: ArchConfig, enc_input):
    """Whisper-style encoder over stubbed frame embeddings (B, S_enc, d)."""
    x = enc_input
    kind = LayerKind(mixer="attn")

    def body(x, p):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        mix = L.attention_train(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            d_head=cfg.head_dim, causal=False)
        x = x + mix
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h, cfg.act)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, cfg: ArchConfig, tokens, *, extras=None, pos0=0,
            remat: bool = True):
    """Training/prefill forward -> logits (B, S, V)."""
    extras = extras or {}
    x = L.embed(params["embed"], tokens)
    if cfg.vision_stub and "patches" in extras:
        npatch = extras["patches"].shape[1]
        x = x.at[:, :npatch].set(extras["patches"].astype(x.dtype))
    memory = None
    if cfg.is_encdec:
        memory = _encode(params, cfg, extras["enc_input"])
    plan = layer_plan(cfg)

    def group_body(x, gp):
        for j, kind in enumerate(plan):
            x = _apply_sublayer(gp[f"sub{j}"], x, cfg, kind,
                                memory=memory, pos0=pos0)
        return x, None

    # remat policy: keep the TP-all-reduced sublayer outputs so the
    # backward recompute never re-runs collectives (§Perf iteration 9);
    # everything else recomputes as usual.
    policy = jax.checkpoint_policies.save_only_these_names("tp_reduced")
    body = jax.checkpoint(group_body, policy=policy) if remat else group_body
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg.logit_softcap)


def loss_fn(params, cfg: ArchConfig, batch, *, remat: bool = True):
    logits = forward(params, cfg, batch["tokens"],
                     extras={k: v for k, v in batch.items()
                             if k not in ("tokens", "labels")},
                     remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ----------------------------------------------------------------- decode ---
def init_cache(params, cfg: ArchConfig, batch: int, max_seq: int,
               kv_dtype=jnp.float32):
    """Per-group stacked cache pytree matching the scan layout."""
    plan = layer_plan(cfg)

    def one_group(gp):
        c = {}
        for j, kind in enumerate(plan):
            sp = gp[f"sub{j}"]
            if kind.mixer == "attn":
                c[f"sub{j}"] = {
                    "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads,
                                    cfg.head_dim), kv_dtype),
                    "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads,
                                    cfg.head_dim), kv_dtype),
                }
            elif kind.mixer == "mamba":
                c[f"sub{j}"] = mamba_lib.init_mamba_cache(sp["mamba"], batch)
            elif kind.mixer == "mlstm":
                c[f"sub{j}"] = xlstm_lib.init_mlstm_cache(sp["mlstm"], batch)
            else:
                c[f"sub{j}"] = xlstm_lib.init_slstm_cache(sp["slstm"], batch)
        return c

    return jax.vmap(one_group)(params["blocks"])


def decode_step(params, cfg: ArchConfig, token, cache, pos, *, extras=None):
    """One-token decode. token: (B, 1) int32; pos: () int32.

    Returns (logits (B, 1, V), cache).
    """
    extras = extras or {}
    x = L.embed(params["embed"], token)
    memory = extras.get("enc_memory")       # pre-encoded for enc-dec serving
    plan = layer_plan(cfg)

    def group_body(x, scanned):
        gp, gc = scanned
        new_c = {}
        for j, kind in enumerate(plan):
            sp = gp[f"sub{j}"]
            c = gc[f"sub{j}"]
            h = L.rmsnorm(sp["ln1"], x, cfg.norm_eps)
            if kind.mixer == "attn":
                mix, ck, cv = L.attention_decode(
                    sp["attn"], h, c["k"], c["v"], pos,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    d_head=cfg.head_dim, window=kind.window,
                    softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta)
                new_c[f"sub{j}"] = {"k": ck, "v": cv}
            elif kind.mixer == "mamba":
                mix, new_c[f"sub{j}"] = mamba_lib.mamba_decode_step(
                    sp["mamba"], h, c, d_state=cfg.d_state)
            elif kind.mixer == "mlstm":
                mix, new_c[f"sub{j}"] = xlstm_lib.mlstm_decode_step(
                    sp["mlstm"], h, c)
            else:
                mix, new_c[f"sub{j}"] = xlstm_lib.slstm_decode_step(
                    sp["slstm"], h, c)
            x = x + mix.astype(x.dtype)
            if kind.cross and memory is not None:
                h = L.rmsnorm(sp["ln_x"], x, cfg.norm_eps)
                y, _, _ = L.attention_decode(
                    sp["cross"], h, c.get("k"), c.get("v"), pos,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    d_head=cfg.head_dim, memory=memory)
                x = x + y.astype(x.dtype)
            if kind.moe:
                h = L.rmsnorm(sp["ln2"], x, cfg.norm_eps)
                t = h.reshape(-1, cfg.d_model)
                y = moe_lib.moe_ffn(sp["moe"], t, n_experts=cfg.n_experts,
                                    top_k=cfg.experts_per_tok, act=cfg.act,
                                    capacity_factor=cfg.moe_capacity_factor)
                if cfg.dense_residual:
                    y = y + L.mlp(sp["dense_mlp"], t, cfg.act)
                x = x + y.reshape(x.shape).astype(x.dtype)
            elif "mlp" in sp:
                h = L.rmsnorm(sp["ln2"], x, cfg.norm_eps)
                x = x + L.mlp(sp["mlp"], h, cfg.act).astype(x.dtype)
        return x, new_c

    x, new_cache = jax.lax.scan(group_body, x, (params["blocks"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg.logit_softcap)
    return logits, new_cache
