"""AdamW + LR schedules, pure JAX (no optax in this environment).

Optimizer state is a pytree mirroring params; ``adamw`` returns
(init_fn, update_fn) closures.  Global-norm clipping and decoupled weight
decay follow the standard formulation.  Moments can be kept in bf16
(``moment_dtype``) to halve optimizer memory at scale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # 'cosine' | 'constant' | 'linear'
    moment_dtype: jnp.dtype = jnp.float32


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, s / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - frac
    else:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def adamw(cfg: AdamWConfig) -> tuple[Callable, Callable]:
    def init(params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros, params),
                          nu=jax.tree_util.tree_map(zeros, params))

    def update(grads, state: AdamWState, params):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr = schedule_lr(cfg, step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = (cfg.b1 * m.astype(jnp.float32)
                 + (1 - cfg.b1) * g32)
            v = (cfg.b2 * v.astype(jnp.float32)
                 + (1 - cfg.b2) * g32 * g32)
            mh = m / (1 - cfg.b1 ** t)
            vh = v / (1 - cfg.b2 ** t)
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:                      # decay matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return (newp.astype(p.dtype), m.astype(cfg.moment_dtype),
                    v.astype(cfg.moment_dtype))

        flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        newp = jax.tree_util.tree_map(lambda x: x[0], flat,
                                      is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree_util.tree_map(lambda x: x[1], flat,
                                      is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree_util.tree_map(lambda x: x[2], flat,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return newp, AdamWState(step=step, mu=newm, nu=newv), {
            "lr": lr, "grad_norm": gnorm}

    return init, update
