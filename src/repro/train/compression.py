"""Gradient compression for cross-pod data parallelism.

At 1000+-node scale the DP all-reduce over the slow pod interconnect
dominates step time; int8 quantization with error feedback cuts those bytes
4x (vs f32) with negligible quality loss, and top-k sparsification goes
further for very-low-bandwidth links.  Both keep a residual (error-feedback)
state so the compression error is re-injected next step — the standard
convergence-preserving construction.

Usage (train loop): grads are compressed *before* the cross-pod reduce and
decompressed after; within-pod reduction stays full precision.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict      # pytree like grads


def init_ef(params) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8_ef(grads, ef: EFState):
    """-> (quantized pytree of (q, scale), new EFState)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        err = x - dequantize_int8(q, s)
        return (q, s), err
    out = jax.tree_util.tree_map(one, grads, ef.residual)
    qs = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=_is_pair)
    errs = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=_is_pair)
    return qs, EFState(residual=errs)


def _is_pair(t):
    return isinstance(t, tuple) and len(t) == 2


def decompress_int8(qs):
    return jax.tree_util.tree_map(
        lambda t: dequantize_int8(*t), qs, is_leaf=_is_pair)


def topk_sparsify(x: jax.Array, frac: float) -> jax.Array:
    """Keep the top ``frac`` fraction by magnitude (dense mask form)."""
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compress_topk_ef(grads, ef: EFState, frac: float = 0.05):
    def one(g, r):
        x = g.astype(jnp.float32) + r
        kept = topk_sparsify(x, frac)
        return kept, x - kept
    out = jax.tree_util.tree_map(one, grads, ef.residual)
    kept = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=_is_pair)
    errs = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=_is_pair)
    return kept, EFState(residual=errs)
