"""Train step construction: grad accumulation, mixed precision, sharding.

``make_train_step(cfg, opt_cfg)`` returns a pure (state, batch) -> (state,
metrics) function suitable for jax.jit with in/out shardings from
launch/mesh.py.  Microbatch accumulation runs as a lax.scan over microbatch
slices (keeps memory at microbatch scale); compute optionally runs in bf16
with f32 master weights.  Optional int8 error-feedback compression applies
to gradients before the (XLA-inserted) DP all-reduce — the compression
round-trip lives inside the step so SPMD reduces the quantized tensors.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.train import compression
from repro.train.optimizer import AdamWConfig, adamw


class TrainState(NamedTuple):
    params: dict
    opt: object
    ef: object | None      # error-feedback residual (grad compression)
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    compute_dtype: str = "bfloat16"     # 'float32' | 'bfloat16'
    remat: bool = True
    grad_compression: str = "none"      # 'none' | 'int8' | 'topk'
    topk_frac: float = 0.05


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def init_state(key, cfg: ArchConfig, opt_cfg: AdamWConfig,
               step_cfg: StepConfig = StepConfig()) -> TrainState:
    params = M.init_params(key, cfg)
    opt_init, _ = adamw(opt_cfg)
    ef = (compression.init_ef(params)
          if step_cfg.grad_compression != "none" else None)
    return TrainState(params=params, opt=opt_init(params), ef=ef,
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    step_cfg: StepConfig = StepConfig()):
    _, opt_update = adamw(opt_cfg)
    cdt = jnp.dtype(step_cfg.compute_dtype)

    def loss_for(cparams, batch):
        return M.loss_fn(cparams, cfg, batch, remat=step_cfg.remat)

    def train_step(state: TrainState, batch):
        # Cast master weights ONCE, outside the microbatch scan: the FSDP
        # weight all-gathers inside then move bf16, not f32, bytes
        # (gradients of cast^T are a pure dtype upcast — §Perf iteration 10)
        cparams = (cast_tree(state.params, cdt)
                   if cdt != jnp.float32 else state.params)
        nmb = step_cfg.microbatches
        if nmb > 1:
            def slice_mb(x):
                b = x.shape[0]
                return x.reshape(nmb, b // nmb, *x.shape[1:])
            mbs = jax.tree_util.tree_map(slice_mb, batch)

            def acc(carry, mb):
                gacc, lacc = carry
                loss, g = jax.value_and_grad(loss_for)(cparams, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + loss), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nmb, gsum)
            loss = lsum / nmb
        else:
            loss, grads = jax.value_and_grad(loss_for)(cparams, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        ef = state.ef
        if step_cfg.grad_compression == "int8":
            qs, ef = compression.compress_int8_ef(grads, ef)
            grads = compression.decompress_int8(qs)
        elif step_cfg.grad_compression == "topk":
            grads, ef = compression.compress_topk_ef(
                grads, ef, step_cfg.topk_frac)

        newp, newopt, om = opt_update(grads, state.opt, state.params)
        new_state = TrainState(params=newp, opt=newopt, ef=ef,
                               step=state.step + 1)
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, step_cfg: StepConfig = StepConfig()):
    cdt = jnp.dtype(step_cfg.compute_dtype)

    def eval_step(params, batch):
        p = cast_tree(params, cdt) if cdt != jnp.float32 else params
        return M.loss_fn(p, cfg, batch, remat=False)

    return eval_step
