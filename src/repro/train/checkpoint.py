"""Checkpointing: atomic, resumable, async-capable, retention-managed.

Pytrees flatten to path-keyed npz archives (np arrays host-side); a JSON
sidecar holds step metadata and the tree structure.  Writes go to a temp
file + atomic rename, so a preempted node never leaves a torn checkpoint —
restore always sees the newest *complete* step (fault-tolerance path).
``save_async`` overlaps the host write with the next training step.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re
import struct
import tempfile
import zlib

import jax
import numpy as np

_SEP = "/"
_executor = cf.ThreadPoolExecutor(max_workers=1)

# Write-ahead-log record framing (serve/streaming.py, DESIGN.md §15):
# little-endian `u32 body_len | u32 crc32(body) | body`.  Length + checksum
# together make torn tails detectable: a record is either completely on
# disk and checksummed, or it is refused by the reader — never half-applied.
_FRAME_HDR = struct.Struct("<II")


def append_framed(path: str, body: bytes) -> None:
    """Append one framed record and fsync before returning.

    The durability half of the WAL contract: when this returns, the record
    survives a process kill or power loss at any later instant (the caller
    acknowledges the mutation only after this returns).  Appends are
    framed (`_FRAME_HDR`) so ``read_framed`` can refuse a tail torn
    mid-write by a kill *during* this call.
    """
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    with open(path, "ab") as f:
        f.write(_FRAME_HDR.pack(len(body), zlib.crc32(body)))
        f.write(body)
        f.flush()
        os.fsync(f.fileno())


def read_framed(path: str) -> tuple[list[bytes], int]:
    """Read every complete checksummed record; returns (bodies, good_bytes).

    Scans frames front-to-back and stops at the first violation — short
    header, short body, or crc mismatch — so a record torn by a mid-write
    kill is *refused*, not half-applied (the crash-recovery contract,
    DESIGN.md §15).  ``good_bytes`` is the byte offset of the last complete
    record's end; the caller truncates the file there before appending
    again so the torn bytes can never resurface.
    """
    with open(path, "rb") as f:
        raw = f.read()
    bodies: list[bytes] = []
    good = 0
    while True:
        hdr = raw[good:good + _FRAME_HDR.size]
        if len(hdr) < _FRAME_HDR.size:
            break
        ln, crc = _FRAME_HDR.unpack(hdr)
        body = raw[good + _FRAME_HDR.size:good + _FRAME_HDR.size + ln]
        if len(body) < ln or zlib.crc32(body) != crc:
            break
        bodies.append(body)
        good += _FRAME_HDR.size + ln
    return bodies, good


def atomic_write_npz(path: str, arrays: dict[str, np.ndarray]) -> None:
    """Write an npz archive via temp-file + atomic rename.

    The write-temp-then-rename idiom the training checkpoints rely on,
    factored out so other durable artifacts (serving index snapshots,
    serve/resilience.py) share one implementation: a preempted writer
    never leaves a torn file at ``path`` — readers see the old complete
    archive or the new one, nothing in between.
    """
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as fh:         # handle: savez won't add .npz
            np.savez(fh, **arrays)
        os.replace(tmp, path)               # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def atomic_write_json(path: str, obj) -> None:
    """Atomic JSON sidecar write (same contract as atomic_write_npz)."""
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         extra: dict | None = None) -> str:
    """Atomic checkpoint write; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    atomic_write_npz(final, flat)
    meta = {"step": step, "keys": sorted(flat), **(extra or {})}
    atomic_write_json(final + ".meta", meta)
    _retain(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, **kw) -> cf.Future:
    """Overlap checkpoint IO with compute; device->host copy happens now."""
    host_tree = jax.tree_util.tree_map(np.asarray, tree)
    return _executor.submit(save, ckpt_dir, step, host_tree, **kw)


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        p = os.path.join(ckpt_dir, f"step_{s:08d}.npz")
        for f in (p, p + ".meta"):
            if os.path.exists(f):
                os.unlink(f)


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.npz", f)
        if m and os.path.exists(os.path.join(ckpt_dir, f) + ".meta"):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like``; returns (tree, step).

    Leaf placement (sharding) follows the example tree when its leaves carry
    shardings (restore-then-reshard for elastic restarts).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, like in paths:
        key = _SEP.join(_part(x) for x in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if hasattr(like, "sharding") and hasattr(like, "shape"):
            leaves.append(jax.device_put(arr, like.sharding))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
