"""Deterministic synthetic LM data pipeline (per-host sharded, resumable).

Every batch is a pure function of (seed, step, shard), so:
  * restart at step S reproduces exactly the batches a fresh run would see
    (skip-ahead is O(1) — no stream replay),
  * each data-parallel host generates only its shard,
  * elastic re-sharding just changes (shard, n_shards) — the global batch
    sequence is invariant.

Tokens follow an order-1 Markov chain (learnable structure so the e2e
example's loss demonstrably falls) plus a [BOS, doc] layout.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_temp: float = 1.5


def _transition_logits(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed ^ 0xDA7A)
    # low-rank structured transitions: learnable but non-trivial
    u = rng.normal(size=(cfg.vocab, 16))
    v = rng.normal(size=(16, cfg.vocab))
    return (u @ v) / cfg.markov_temp


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        logits = _transition_logits(cfg)
        z = logits - logits.max(axis=1, keepdims=True)
        p = np.exp(z)
        self.probs = p / p.sum(axis=1, keepdims=True)
        self.cum = np.cumsum(self.probs, axis=1)

    def batch(self, step: int, *, shard: int = 0, n_shards: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        b = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed * 0x9E3779B1 + step) * 65_521 + shard)
        toks = np.empty((b, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, b)
        unif = rng.random((b, cfg.seq_len))
        for t in range(cfg.seq_len):
            row = self.cum[toks[:, t]]
            toks[:, t + 1] = (unif[:, t:t + 1] < row).argmax(axis=1)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    def global_batch(self, step: int) -> dict:
        return self.batch(step, shard=0, n_shards=1)


def optimal_loss(cfg: DataConfig, n_samples: int = 4096) -> float:
    """Entropy rate of the Markov source — the floor the LM can reach."""
    ds = SyntheticLM(cfg)
    rng = np.random.default_rng(1)
    rows = rng.integers(0, cfg.vocab, n_samples)
    p = ds.probs[rows]
    return float(-(p * np.log(np.maximum(p, 1e-12))).sum(axis=1).mean())
