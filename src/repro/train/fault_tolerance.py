"""Fault tolerance & elasticity for multi-pod training.

Pieces (all exercised by tests/test_fault_tolerance.py):

  * ``HeartbeatMonitor`` — per-worker liveness with a deadline; the launcher
    polls ``dead_workers()`` each step and triggers checkpoint-restore with a
    shrunken mesh when a pod stops beating.
  * ``run_resumable`` — the supervisor loop: run steps, checkpoint every K,
    on failure restore the latest complete checkpoint and continue.  Handles
    the "torn step" case by construction (checkpoints are atomic).
  * ``StragglerMitigator`` — tracks per-step durations; steps slower than
    p50 * tolerance are flagged and the shard is re-dispatched (backup-task
    pattern).  In single-controller JAX the redundant dispatch is simulated;
    on a real fleet this maps to re-queuing the slow host's program.
  * ``elastic_reshard`` — re-shards a host checkpoint onto a new mesh
    (device count changed): restore is placement-driven, so this is restore
    with new shardings + a data-pipeline shard remap.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib


class HeartbeatMonitor:
    def __init__(self, workers: list[str], timeout_s: float = 60.0):
        self.timeout = timeout_s
        self.last: dict[str, float] = {w: time.monotonic() for w in workers}

    def beat(self, worker: str, at: float | None = None):
        self.last[worker] = at if at is not None else time.monotonic()

    def dead_workers(self, now: float | None = None) -> list[str]:
        now = now if now is not None else time.monotonic()
        return [w for w, t in self.last.items() if now - t > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_workers()


@dataclasses.dataclass
class StragglerMitigator:
    tolerance: float = 2.0
    history: list = dataclasses.field(default_factory=list)
    window: int = 64

    def record(self, seconds: float) -> bool:
        """Returns True if this step counted as a straggler."""
        self.history.append(seconds)
        self.history = self.history[-self.window:]
        if len(self.history) < 8:
            return False
        p50 = float(np.median(self.history))
        return seconds > self.tolerance * p50

    def deadline(self) -> float | None:
        if len(self.history) < 8:
            return None
        return self.tolerance * float(np.median(self.history))


def run_resumable(
    state,
    step_fn: Callable,
    batch_fn: Callable[[int], dict],
    *,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    fail_injector: Callable[[int], bool] | None = None,
    max_restarts: int = 10,
    on_metrics: Callable[[int, dict], None] | None = None,
):
    """Supervisor loop: step, checkpoint, restore-on-failure.

    ``fail_injector(step) -> bool`` simulates node failure (tests); a real
    deployment reaches the same code path via exceptions from the runtime.
    Returns (final_state, steps_run, n_restarts).
    """
    start = int(state.step)
    restarts = 0
    step = start
    while step < n_steps:
        try:
            if fail_injector is not None and fail_injector(step):
                raise RuntimeError(f"injected failure at step {step}")
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % ckpt_every == 0 or step == n_steps:
                ckpt_lib.save(ckpt_dir, step, state)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            last = ckpt_lib.latest_step(ckpt_dir)
            if last is None:
                step = start          # nothing saved yet: restart from init
                continue
            state, step = ckpt_lib.restore(ckpt_dir, state, last)
    return state, step, restarts


def elastic_reshard(ckpt_dir: str, template_state, *, step: int | None = None):
    """Restore the latest checkpoint onto a *new* mesh/sharding layout.

    ``template_state`` carries the target shardings (built under the new
    mesh); restore places each host array per the template.  The caller
    remaps data shards by the new (shard, n_shards).
    """
    return ckpt_lib.restore(ckpt_dir, template_state, step)
