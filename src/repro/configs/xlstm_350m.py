"""xlstm-350m [ssm]: sLSTM + mLSTM blocks (7:1). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm_350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    ssm="xlstm", slstm_period=8,
    tie_embeddings=True,
    sub_quadratic=True,
    notes="mLSTM blocks (pf=2 internal) + 1 sLSTM per 8 with 4/3 FFN",
)
