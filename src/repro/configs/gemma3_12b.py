"""gemma3-12b [dense]: 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144,
    window=1024, local_global_period=6,
    act="swiglu", tie_embeddings=True, rope_theta=1e6,
    sub_quadratic=True,   # 5/6 of layers sliding-window
    notes="5 local : 1 global per period of 6",
)
