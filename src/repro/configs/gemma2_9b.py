"""gemma2-9b [dense]: local+global alternating attention, logit softcap.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2_9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab=256000,
    window=4096, local_global_period=2,
    attn_softcap=50.0, logit_softcap=30.0,
    act="swiglu", tie_embeddings=True,
    sub_quadratic=True,   # half the layers are sliding-window
    notes="1:1 local:global alternation; softcaps per gemma2",
)
