"""Architecture configuration schema + shape registry.

Every assigned architecture is a frozen ``ArchConfig``; the four input
shapes (train_4k / prefill_32k / decode_32k / long_500k) are global
constants.  ``smoke()`` derives the reduced same-family config used by the
CPU smoke tests (full configs are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 2
    moe_period: int = 1         # MoE every `moe_period` layers (if experts>0)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    moe_capacity_factor: float = 1.25  # train-time drop policy (GShard)
    # --- attention pattern ---
    window: int = 0             # sliding-window size for local layers
    local_global_period: int = 0  # every p-th layer is global (others local)
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    # --- hybrid / ssm ---
    attn_period: int = 0        # jamba: 1 attention layer per `attn_period`
    ssm: str = ""               # '' | 'mamba' | 'xlstm'
    slstm_period: int = 0       # xlstm: 1 sLSTM per `slstm_period` blocks
    d_state: int = 16
    # --- enc-dec / multimodal ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500         # whisper audio frames (stubbed embeddings)
    vision_stub: bool = False
    n_patches: int = 0
    # --- misc ---
    act: str = "swiglu"
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sub_quadratic: bool = False  # eligible for long_500k
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        """Length of the repeating layer pattern (scan group size)."""
        p = 1
        for v in (self.moe_period, self.local_global_period, self.attn_period,
                  self.slstm_period):
            if v:
                p = _lcm(p, v)
        return p

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers,
                                                  self.period)
        return self.n_layers // self.period

    def _period_params(self) -> int:
        """Analytic parameter count of one period of layers."""
        d, f = self.d_model, self.d_ff
        dh = self.head_dim
        n_attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        n_mlp = d * f * (3 if self.act == "swiglu" else 2)
        total = 0
        for kind in _plan(self):
            if kind.mixer == "attn":
                total += n_attn
            elif kind.mixer == "mamba":
                di = 2 * d
                total += (d * 2 * di + di * d
                          + di * (d // 16 + 2 * self.d_state)
                          + (d // 16) * di)
            elif kind.mixer == "mlstm":
                di = 2 * d
                total += d * 2 * di + di * d + 3 * di * di + 2 * di
            elif kind.mixer == "slstm":
                total += 4 * d * d + d * d + 4 * d * (d // self.n_heads)
            if kind.moe:
                total += self.n_experts * n_mlp + d * self.n_experts
                if self.dense_residual:
                    total += n_mlp
            elif kind.mlp:
                total += n_mlp
        return total

    def total_params(self) -> int:
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.is_encdec:
            dh = self.head_dim
            n_attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
            n_mlp = d * self.d_ff * (3 if self.act == "swiglu" else 2)
            enc = self.n_enc_layers * (n_attn + n_mlp)
            emb += self.n_layers * n_attn            # decoder cross-attn
        return emb + enc + self._period_params() * self.n_groups

    def active_params_per_token(self) -> int:
        """N_active for the 6*N*D MoE roofline convention."""
        if not self.n_experts:
            return self.total_params()
        d, f = self.d_model, self.d_ff
        n_mlp = d * f * (3 if self.act == "swiglu" else 2)
        moe_layers = sum(1 for k in _plan(self) if k.moe) * self.n_groups
        inactive = moe_layers * (self.n_experts - self.experts_per_tok) * n_mlp
        return self.total_params() - inactive

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=self.period * (2 if self.period <= 4 else 1),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            window=min(self.window, 32) if self.window else 0,
            enc_seq=24,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_patches=min(self.n_patches, 8),
        )


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


def _plan(cfg):
    from repro.models.model import layer_plan
    return layer_plan(cfg)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
