"""grok-1-314b [moe]: 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok_1_314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    n_experts=8, experts_per_tok=2, moe_period=1,
    attn_softcap=30.0,
    sub_quadratic=False,
    notes="8 experts < TP axis: experts replicate, expert d_ff TP-shards",
)
