"""jamba-v0.1-52b [hybrid]: mamba+attn 1:7 interleave, 16-expert MoE.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba_v01_52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    n_experts=16, experts_per_tok=2, moe_period=2,
    ssm="mamba", attn_period=8, d_state=16,
    sub_quadratic=True,
    notes="period 8: 1 attention + 7 mamba; MoE every 2nd layer",
)
