"""whisper-small [audio]: enc-dec, conv frontend stubbed as precomputed
frame embeddings. 12L decoder + 12L encoder, MHA (kv=12).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865,
    is_encdec=True, n_enc_layers=12, enc_seq=1500,
    act="gelu", tie_embeddings=True,
    sub_quadratic=False,
    notes="audio frontend stub: input_specs provides frame embeddings",
)
