"""arctic-480b [moe]: 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic_480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, experts_per_tok=2, moe_period=1, dense_residual=True,
    sub_quadratic=False,
    notes="dense-MoE hybrid: dense FFN residual in parallel with MoE",
)
