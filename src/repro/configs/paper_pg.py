"""The paper's own 'architecture': FastPGT tuning workload defaults."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PGWorkload:
    name: str = "paper_pg"
    n: int = 4000           # dataset size (laptop scale; paper: 1e6)
    d: int = 64             # Sift-class dimensionality scaled
    n_queries: int = 200
    k: int = 10
    budget: int = 40        # configs explored (paper: 100)
    batch: int = 10         # mEHVI batch (paper: 10)
    pg: str = "vamana"


CONFIG = PGWorkload()
