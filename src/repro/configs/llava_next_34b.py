"""llava-next-34b [vlm]: yi-34b backbone, anyres patch embeddings stubbed.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava_next_34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000,
    vision_stub=True, n_patches=1152,
    rope_theta=5e6,
    sub_quadratic=False,
    notes="anyres tiling stub: input_specs provides patch embeddings",
)
