"""Architecture registry: --arch <id> resolution + input specs per shape.

``input_specs(cfg, shape, mesh)`` returns ShapeDtypeStructs for every model
input — the dry-run lowers against these (no allocation).  Modality
frontends are stubs: whisper supplies precomputed frame embeddings, llava
precomputed patch embeddings (per assignment).
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig

ARCH_IDS = [
    "whisper_small", "granite_3_8b", "yi_34b", "gemma2_9b", "gemma3_12b",
    "arctic_480b", "grok_1_314b", "jamba_v01_52b", "xlstm_350m",
    "llava_next_34b",
]


def get_config(arch: str) -> ArchConfig:
    arch = arch.replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Apply the assignment's skip rules; returns (runnable, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch — long_500k skipped (spec)"
    if shape.name == "long_500k" and cfg.is_encdec:
        return False, "enc-dec decoder bound to encoder memory"
    return True, ""


def runnable_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, _ = cell_is_runnable(cfg, shape)
            if ok:
                cells.append((arch, sname))
    return cells


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
        if cfg.is_encdec:
            batch["enc_input"] = _sds((b, cfg.enc_seq, cfg.d_model),
                                      jnp.float32)
        if cfg.vision_stub:
            batch["patches"] = _sds((b, cfg.n_patches, cfg.d_model),
                                    jnp.float32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.is_encdec:
            batch["enc_input"] = _sds((b, cfg.enc_seq, cfg.d_model),
                                      jnp.float32)
        if cfg.vision_stub:
            batch["patches"] = _sds((b, cfg.n_patches, cfg.d_model),
                                    jnp.float32)
        return batch
    # decode: one new token against a seq_len KV cache / recurrent state
    batch = {
        "token": _sds((b, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }
    if cfg.is_encdec:
        batch["enc_memory"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch
