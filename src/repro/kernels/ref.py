"""Pure-jnp reference oracles for every Pallas kernel in this package.

Each function here defines the *semantics* a kernel must match; tests sweep
shapes/dtypes and ``assert_allclose`` kernel output against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib


def pairwise_distance_ref(q: jax.Array, x: jax.Array,
                          kernel: str = "l2") -> jax.Array:
    """Pairwise distances under a kernel form (core/metric.py convention).

    Args:
      q: (nq, d) queries.
      x: (nx, d) base vectors.
      kernel: "l2" -> squared L2; "ip" -> 1 - <q, x> (cosine = "ip" over
              unit-normalized inputs, handled at the ops boundary).
    Returns:
      (nq, nx) float32 distances.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    cross = q @ x.T                                      # (nq, nx)
    if kernel == "ip":
        return 1.0 - cross
    qn = jnp.sum(q * q, axis=-1, keepdims=True)          # (nq, 1)
    xn = jnp.sum(x * x, axis=-1, keepdims=True).T        # (1, nx)
    d2 = qn + xn - 2.0 * cross
    return jnp.maximum(d2, 0.0)


def l2_distance_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Back-compat wrapper: squared-L2 form of ``pairwise_distance_ref``."""
    return pairwise_distance_ref(q, x, "l2")


def gather_distance_ref(
    u: jax.Array,
    c: jax.Array,
    cached: jax.Array | None = None,
    mask: jax.Array | None = None,
    kernel: str = "l2",
) -> jax.Array:
    """Distances from each query to its own gathered candidates.

    This oracle is also the CPU dispatch path (ops.py), so its numerics
    define host-side search results bit-for-bit; the MXU kernel's l2
    norm-expansion (kernels/gather_distance.py) matches it to float
    tolerance only.

    Args:
      u: (b, d) queries.
      c: (b, k, d) per-query candidate vectors (already gathered).
      cached: optional (b, k) previously computed distances.
      mask: optional (b, k) bool; True = "must compute" (cache miss).
            Where False, ``cached`` is passed through unchanged. This encodes
            the paper's V_delta reuse semantics (FastPGT Alg. 3 line 6-9).
      kernel: "l2" or "ip" (see ``pairwise_distance_ref``).
    Returns:
      (b, k) float32 distances.
    """
    u = u.astype(jnp.float32)
    c = c.astype(jnp.float32)
    d2 = metric_lib.kernel_distance(c, u[:, None, :], kernel)
    if mask is not None:
        assert cached is not None
        d2 = jnp.where(mask, d2, cached.astype(jnp.float32))
    return d2


def pairwise_distance_sq8_ref(q: jax.Array, codes: jax.Array,
                              scale: jax.Array, cnorms: jax.Array,
                              kernel: str = "l2") -> jax.Array:
    """Pairwise distances against an int8 scalar-quantized corpus.

    The quantized forms' semantic oracle AND the CPU dispatch path
    (ops.py), mirroring ``pairwise_distance_ref``.  ADC formulation
    (DESIGN.md §16): the fp32 query is pre-scaled once, the cross term is
    one fp32 dot against the upcast codes — identical operand shapes and
    contraction to the Pallas kernel so interpret mode bit-matches.

    Args:
      q: (nq, d) fp32 queries in prepared space.
      codes: (nx, d) int8 corpus codes.
      scale: (d,) per-dimension symmetric scale.
      cnorms: (nx,) squared norms of the dequantized rows.
      kernel: "l2" | "ip" (core/metric.py convention).
    Returns:
      (nq, nx) float32 distances to the dequantized corpus.
    """
    q = q.astype(jnp.float32)
    qs = q * scale[None, :]
    cross = jax.lax.dot_general(
        qs, codes.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (nq, nx)
    if kernel == "ip":
        return 1.0 - cross
    qn = jnp.sum(q * q, axis=-1, keepdims=True)              # (nq, 1)
    return jnp.maximum((cnorms[None, :] + qn) - 2.0 * cross, 0.0)


def gather_distance_sq8_ref(
    u: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    cnorms: jax.Array,
    cached: jax.Array | None = None,
    mask: jax.Array | None = None,
    kernel: str = "l2",
) -> jax.Array:
    """Gathered distances against int8 codes, V_delta cache semantics.

    The quantized twin of ``gather_distance_ref`` — also the CPU dispatch
    path.  Same ADC formulation as ``pairwise_distance_sq8_ref``; the
    Pallas tile computes the same math as a (bk, d) gemm, which interpret
    mode matches to fp32 accumulation tolerance (tests/test_kernels.py).

    Args:
      u: (b, d) fp32 queries in prepared space.
      codes: (b, k, d) int8 per-query gathered candidate codes.
      scale: (d,) per-dimension symmetric scale.
      cnorms: (b, k) squared norms of the dequantized candidates.
      cached/mask: V_delta reuse as in ``gather_distance_ref``.
    Returns:
      (b, k) float32 distances to the dequantized candidates.
    """
    u = u.astype(jnp.float32)
    qs = u * scale[None, :]
    cross = jax.lax.dot_general(
        codes.astype(jnp.float32), qs,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                  # (b, k)
    if kernel == "ip":
        d2 = 1.0 - cross
    else:
        qn = jnp.sum(u * u, axis=-1, keepdims=True)          # (b, 1)
        d2 = jnp.maximum((cnorms + qn) - 2.0 * cross, 0.0)
    if mask is not None:
        assert cached is not None
        d2 = jnp.where(mask, d2, cached.astype(jnp.float32))
    return d2


def _window_mask(sq: int, sk: int, q_off: int, causal: bool, window: int) -> jax.Array:
    """Boolean (sq, sk) mask; True = attend."""
    qi = q_off + jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), dtype=bool)
    if causal:
        m &= ki <= qi
    if window > 0:
        m &= ki > qi - window
    return m


def flash_attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """XLA flash attention: lax.scan over KV chunks with online softmax.

    Same computation graph the Pallas kernel performs, expressed in pure
    jnp — the memory-bounded path used for long sequences on backends
    without Pallas (dry-run lowering, CPU tests).  Matches
    flash_attention_ref to float tolerance.
    """
    orig_dtype = q.dtype
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    s = (1.0 / (dh ** 0.5)) if scale is None else scale
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nkc = (sk + pad) // chunk
    q32 = q.astype(jnp.float32) * s
    kc = jnp.moveaxis(k.astype(jnp.float32).reshape(b, h, nkc, chunk, dh),
                      2, 0)
    vc = jnp.moveaxis(v.astype(jnp.float32).reshape(b, h, nkc, chunk, dh),
                      2, 0)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc, j = carry
        kj, vj = inp
        logits = jnp.einsum("bhqd,bhkd->bhqk", q32, kj)
        if softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        kpos = j * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vj)
        return (m_new, l_new, acc_new, j + 1), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)),
                                     (kc, vc))
    out = acc / jnp.where(l > 0, l, 1.0)[..., None]
    return out.astype(orig_dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference attention.

    Args:
      q: (b, h, sq, dh); k, v: (b, h, sk, dh) — GQA repeat happens *outside*.
      causal: apply causal mask (query i attends keys <= q_offset + i).
      window: if > 0, sliding local window (attend keys in (qi-window, qi]).
      softcap: if > 0, logits = softcap * tanh(logits / softcap) (gemma2-style).
      scale: logit scale; default 1/sqrt(dh).
      q_offset: absolute position of q[0] relative to k[0] (decode/prefill-chunk).
    Returns:
      (b, h, sq, dh) in q.dtype.
    """
    orig_dtype = q.dtype
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    dh = q.shape[-1]
    s = (1.0 / jnp.sqrt(dh)) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * s
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    m = _window_mask(q.shape[2], k.shape[2], q_offset, causal, window)
    logits = jnp.where(m[None, None], logits, -jnp.inf)
    # Rows that are fully masked (can happen with tiny windows) -> zeros.
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return out.astype(orig_dtype)
