"""Blocked flash attention Pallas kernel for the LM substrate.

Targets the TPU MXU with (bq, dh)x(dh, bk) logit tiles and online softmax;
supports the features the assigned architectures need:

  * causal masking (decoder LMs)
  * sliding local window (gemma2/gemma3 local layers)
  * logit soft-capping ``softcap * tanh(logits / softcap)`` (gemma2)
  * ``q_offset`` for chunked prefill / decode against a longer KV

Grid: (batch*heads, sq/bq, sk/bk) with the KV axis innermost; (m, l, acc)
accumulators live in VMEM scratch and are carried across KV steps, so each
(q-block) owns a single running softmax — the standard flash formulation.

Fully-masked KV blocks are *masked*, not skipped, so the kernel stays a
static grid (interpret-mode friendly); on hardware a causal-block skip via
``pl.when`` on the block index is a straightforward extension and is noted
in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
_NEG_INF = -1e30  # finite sentinel: avoids inf-inf NaNs in online softmax


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale, causal, window, softcap, q_offset, bq, bk, nkb, kv_len):
    qi_blk = pl.program_id(1)
    kv_blk = pl.program_id(2)

    @pl.when(kv_blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                      # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                      # (bk, dh)
    v = v_ref[0].astype(jnp.float32)                      # (bk, dh)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # (bq, bk)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)

    qpos = q_offset + qi_blk * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = kv_blk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len  # zero-padded KV tail is never attended
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_ref[...]                                    # (bq, 1)
    l_prev = l_ref[...]                                    # (bq, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                        # <= 1
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (bq, dh)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_blk == nkb - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset",
                     "bq", "bk", "kv_len", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    q_offset: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    kv_len: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """(bh, sq, dh), (bh, sk, dh), (bh, sk, dh) -> (bh, sq, dh).

    Batch and (already GQA-repeated) head dims must be flattened into the
    leading axis.  sq % bq == 0 and sk % bk == 0 (ops.py pads).
    """
    bh, sq, dh = q.shape
    _, sk, _ = k.shape
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    s = float(scale) if scale is not None else 1.0 / (dh ** 0.5)
    nkb = sk // bk
    grid = (bh, sq // bq, nkb)
    kernel = functools.partial(
        _fa_kernel, scale=s, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, bq=bq, bk=bk, nkb=nkb,
        kv_len=sk if kv_len is None else kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
