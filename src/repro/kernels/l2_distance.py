"""Blocked pairwise distance Pallas kernel — the Search hot spot.

FastPGT's parameter-estimation cost is dominated by distance computations in
the beam-search (Search) phase of PG construction.  On TPU both kernel forms
reduce to one MXU matmul per tile:

  l2:  ||q - x||^2 = ||q||^2 + ||x||^2 - 2 q.x   (cross term on the MXU)
  ip:  1 - q.x                                    (pure MXU + affine)

Cosine is ip over unit-normalized inputs; normalization happens at the
``ops.py`` boundary (or once per dataset in the builders) so the kernel
stays a fused matmul.  The kernel tiles (nq, nx, d) into VMEM-resident
blocks:

  grid = (nq/bq, nx/bx)
  q tile   : (bq, d)   VMEM
  x tile   : (bx, d)   VMEM
  out tile : (bq, bx)  VMEM

``d`` stays un-blocked (PG datasets have d <= 1024; a 128x1024 f32 tile is
512 KiB, well within the ~16 MiB VMEM budget at the default block sizes).
Block sizes default to MXU-aligned 128x128; the ops.py wrapper pads inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BQ = 128
DEFAULT_BX = 128


def _dist_kernel(q_ref, x_ref, o_ref, *, kernel: str):
    q = q_ref[...].astype(jnp.float32)                    # (bq, d)
    x = x_ref[...].astype(jnp.float32)                    # (bx, d)
    # MXU: (bq, d) @ (d, bx)
    cross = jax.lax.dot_general(
        q, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if kernel == "ip":
        o_ref[...] = 1.0 - cross
    else:
        qn = jnp.sum(q * q, axis=-1, keepdims=True)       # (bq, 1)
        xn = jnp.sum(x * x, axis=-1, keepdims=True)       # (bx, 1)
        o_ref[...] = jnp.maximum(qn + xn.T - 2.0 * cross, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("kernel", "bq", "bx", "interpret"))
def pairwise_distance(
    q: jax.Array,
    x: jax.Array,
    *,
    kernel: str = "l2",
    bq: int = DEFAULT_BQ,
    bx: int = DEFAULT_BX,
    interpret: bool = False,
) -> jax.Array:
    """Pairwise distances via pallas_call; ``kernel`` in {"l2", "ip"}.

    Shapes must be pre-padded: nq % bq == 0, nx % bx == 0.
    Returns (nq, nx) float32.
    """
    nq, d = q.shape
    nx, d2 = x.shape
    assert d == d2, (d, d2)
    assert nq % bq == 0 and nx % bx == 0, (nq, nx, bq, bx)
    grid = (nq // bq, nx // bx)
    return pl.pallas_call(
        functools.partial(_dist_kernel, kernel=kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bx, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nx), jnp.float32),
        interpret=interpret,
    )(q, x)


def l2_distance(q: jax.Array, x: jax.Array, **kw) -> jax.Array:
    """Back-compat wrapper: squared-L2 form of ``pairwise_distance``."""
    return pairwise_distance(q, x, kernel="l2", **kw)


def _dist_sq8_kernel(qs_ref, qn_ref, c_ref, cn_ref, o_ref, *, kernel: str):
    """Int8 MXU form (DESIGN.md §16): the corpus tile arrives as int8
    codes (4× less HBM→VMEM traffic than fp32 — the point of SQ8) and is
    upcast in-register; the query tile is already pre-scaled by the
    per-dimension SQ scale (ADC), so the cross term prices distances to
    the dequantized corpus exactly.  l2 uses the precomputed dequantized
    row norms (``cn``) instead of re-deriving them from the codes."""
    qs = qs_ref[...].astype(jnp.float32)                  # (bq, d) q·scale
    c = c_ref[...].astype(jnp.float32)                    # (bx, d) int8 codes
    # MXU: (bq, d) @ (d, bx)
    cross = jax.lax.dot_general(
        qs, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if kernel == "ip":
        o_ref[...] = 1.0 - cross
    else:
        qn = qn_ref[...]                                  # (bq, 1) ‖q‖²
        cn = cn_ref[...]                                  # (bx, 1) ‖ĉ‖²
        o_ref[...] = jnp.maximum((cn.T + qn) - 2.0 * cross, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("kernel", "bq", "bx", "interpret"))
def pairwise_distance_sq8(
    qs: jax.Array,
    qn: jax.Array,
    codes: jax.Array,
    cn: jax.Array,
    *,
    kernel: str = "l2",
    bq: int = DEFAULT_BQ,
    bx: int = DEFAULT_BX,
    interpret: bool = False,
) -> jax.Array:
    """Pairwise distances against int8 codes via pallas_call.

    Args (pre-padded: nq % bq == 0, nx % bx == 0):
      qs: (nq, d) f32 pre-scaled queries (q · scale).
      qn: (nq, 1) f32 squared query norms (l2 form; pass zeros for ip).
      codes: (nx, d) int8 corpus codes.
      cn: (nx, 1) f32 dequantized-row squared norms.
    Returns (nq, nx) float32.
    """
    nq, d = qs.shape
    nx, d2 = codes.shape
    assert d == d2, (d, d2)
    assert nq % bq == 0 and nx % bx == 0, (nq, nx, bq, bx)
    grid = (nq // bq, nx // bx)
    return pl.pallas_call(
        functools.partial(_dist_sq8_kernel, kernel=kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bx, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bx, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nx), jnp.float32),
        interpret=interpret,
    )(qs, qn, codes, cn)
