"""Blocked pairwise squared-L2 distance Pallas kernel — the Search hot spot.

FastPGT's parameter-estimation cost is dominated by distance computations in
the beam-search (Search) phase of PG construction.  On TPU we reformulate
``||q - x||^2 = ||q||^2 + ||x||^2 - 2 q.x`` so the dominant cross term is an
MXU matmul.  The kernel tiles (nq, nx, d) into VMEM-resident blocks:

  grid = (nq/bq, nx/bx)
  q tile   : (bq, d)   VMEM
  x tile   : (bx, d)   VMEM
  out tile : (bq, bx)  VMEM

``d`` stays un-blocked (PG datasets have d <= 1024; a 128x1024 f32 tile is
512 KiB, well within the ~16 MiB VMEM budget at the default block sizes).
Block sizes default to MXU-aligned 128x128; the ops.py wrapper pads inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BQ = 128
DEFAULT_BX = 128


def _l2_kernel(q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                    # (bq, d)
    x = x_ref[...].astype(jnp.float32)                    # (bx, d)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)           # (bq, 1)
    xn = jnp.sum(x * x, axis=-1, keepdims=True)           # (bx, 1)
    # MXU: (bq, d) @ (d, bx)
    cross = jax.lax.dot_general(
        q, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jnp.maximum(qn + xn.T - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("bq", "bx", "interpret"))
def l2_distance(
    q: jax.Array,
    x: jax.Array,
    *,
    bq: int = DEFAULT_BQ,
    bx: int = DEFAULT_BX,
    interpret: bool = False,
) -> jax.Array:
    """Pairwise squared L2 distances via pallas_call.

    Shapes must be pre-padded: nq % bq == 0, nx % bx == 0.
    Returns (nq, nx) float32.
    """
    nq, d = q.shape
    nx, d2 = x.shape
    assert d == d2, (d, d2)
    assert nq % bq == 0 and nx % bx == 0, (nq, nx, bq, bx)
    grid = (nq // bq, nx // bx)
    return pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bx, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nx), jnp.float32),
        interpret=interpret,
    )(q, x)
