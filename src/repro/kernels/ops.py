"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy:
  * TPU backend           -> compiled Pallas kernel.
  * CPU (this container)  -> pure-jnp reference (fast, same semantics), unless
                             ``REPRO_PALLAS_INTERPRET=1`` forces interpret-mode
                             Pallas (used by tests to validate the kernels).

All wrappers pad to kernel block alignment and strip padding on the way out,
so callers never see alignment constraints.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib
from repro.kernels import flash_attention as _fa
from repro.kernels import gather_distance as _gd
from repro.kernels import l2_distance as _l2
from repro.kernels import ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _use_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


def pairwise_distance(q: jax.Array, x: jax.Array,
                      metric: "str | metric_lib.Metric" = "l2") -> jax.Array:
    """Pairwise metric distances: (nq, d), (nx, d) -> (nq, nx) f32.

    ``metric`` may be any registered metric; cosine unit-normalizes both
    sides here so the kernel stays a fused matmul (callers that own the
    dataset normalize once via ``Metric.prepare`` and pass the "ip" kernel
    form instead — re-normalizing unit vectors is a numeric no-op).
    """
    met = metric_lib.resolve(metric)
    if met.normalize:
        q = metric_lib.normalize(q)
        x = metric_lib.normalize(x)
    if not (_use_pallas() or _use_interpret()):
        return ref.pairwise_distance_ref(q, x, met.kernel)
    nq, nx = q.shape[0], x.shape[0]
    bq = min(_l2.DEFAULT_BQ, max(8, nq))
    bx = min(_l2.DEFAULT_BX, max(8, nx))
    qp = _pad_to(_pad_to(q, 0, bq), 1, 128)
    xp = _pad_to(_pad_to(x, 0, bx), 1, 128)
    out = _l2.pairwise_distance(qp, xp, kernel=met.kernel, bq=bq, bx=bx,
                                interpret=_use_interpret())
    return out[:nq, :nx]


def l2_distance(q: jax.Array, x: jax.Array) -> jax.Array:
    """Pairwise squared L2: (nq, d), (nx, d) -> (nq, nx) f32."""
    return pairwise_distance(q, x, "l2")


def gather_distance(u, c, cached=None, mask=None,
                    metric: "str | metric_lib.Metric" = "l2") -> jax.Array:
    """V_delta-aware gathered distances: see kernels/gather_distance.py."""
    met = metric_lib.resolve(metric)
    if met.normalize:
        u = metric_lib.normalize(u)
        c = metric_lib.normalize(c)
    b, k = c.shape[0], c.shape[1]
    if cached is None:
        cached = jnp.zeros((b, k), jnp.float32)
        mask = jnp.ones((b, k), dtype=bool)
    if not (_use_pallas() or _use_interpret()):
        return ref.gather_distance_ref(u, c, cached, mask, met.kernel)
    bk = min(_gd.DEFAULT_BK, max(8, k))
    cp = _pad_to(c, 1, bk)
    cachedp = _pad_to(cached, 1, bk)
    maskp = _pad_to(mask, 1, bk, value=True)
    up = _pad_to(u, 1, 128)
    cp = _pad_to(cp, 2, 128)
    out = _gd.gather_distance(up, cp, cachedp, maskp, kernel=met.kernel,
                              bk=bk, interpret=_use_interpret())
    return out[:, :k]


def pairwise_distance_q(q: jax.Array, quant,
                        metric: "str | metric_lib.Metric" = "l2"
                        ) -> jax.Array:
    """Pairwise distances against an SQ8 corpus (DESIGN.md §16).

    ``quant`` is a ``metric.QuantizedData`` over prepared-space vectors;
    the query stays fp32 (cosine normalizes it here) and is pre-scaled by
    the SQ scale once (ADC).  Returns (nq, nx) f32 distances to the
    dequantized corpus.
    """
    met = metric_lib.resolve(metric)
    if met.normalize:
        q = metric_lib.normalize(q)
    codes, scale, cnorms = quant.codes, quant.scale, quant.norms
    if not (_use_pallas() or _use_interpret()):
        return ref.pairwise_distance_sq8_ref(q, codes, scale, cnorms,
                                             met.kernel)
    q = q.astype(jnp.float32)
    qs = q * scale[None, :]
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    nq, nx = q.shape[0], codes.shape[0]
    bq = min(_l2.DEFAULT_BQ, max(8, nq))
    bx = min(_l2.DEFAULT_BX, max(8, nx))
    qsp = _pad_to(_pad_to(qs, 0, bq), 1, 128)
    qnp = _pad_to(qn, 0, bq)
    cp = _pad_to(_pad_to(codes, 0, bx), 1, 128)
    cnp = _pad_to(cnorms[:, None], 0, bx)
    out = _l2.pairwise_distance_sq8(qsp, qnp, cp, cnp, kernel=met.kernel,
                                    bq=bq, bx=bx,
                                    interpret=_use_interpret())
    return out[:nq, :nx]


def gather_distance_q(u, codes, scale, cnorms, cached=None, mask=None,
                      metric: "str | metric_lib.Metric" = "l2") -> jax.Array:
    """V_delta-aware gathered distances against SQ8 codes (DESIGN.md §16).

    ``u`` (b, d) fp32 queries, ``codes`` (b, k, d) int8 gathered candidate
    codes, ``scale`` (d,), ``cnorms`` (b, k) dequantized-row norms;
    cache semantics as in ``gather_distance``.
    """
    met = metric_lib.resolve(metric)
    if met.normalize:
        u = metric_lib.normalize(u)
    b, k = codes.shape[0], codes.shape[1]
    if cached is None:
        cached = jnp.zeros((b, k), jnp.float32)
        mask = jnp.ones((b, k), dtype=bool)
    if not (_use_pallas() or _use_interpret()):
        return ref.gather_distance_sq8_ref(u, codes, scale, cnorms, cached,
                                           mask, met.kernel)
    u = u.astype(jnp.float32)
    qs = u * scale[None, :]
    qn = jnp.sum(u * u, axis=-1, keepdims=True)
    bk = min(_gd.DEFAULT_BK, max(8, k))
    cp = _pad_to(_pad_to(codes, 1, bk), 2, 128)
    cnp = _pad_to(cnorms, 1, bk)
    cachedp = _pad_to(cached, 1, bk)
    maskp = _pad_to(mask, 1, bk, value=True)
    qsp = _pad_to(qs, 1, 128)
    out = _gd.gather_distance_sq8(qsp, qn, cp, cnp, cachedp, maskp,
                                  kernel=met.kernel, bk=bk,
                                  interpret=_use_interpret())
    return out[:, :k]


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, q_offset=0) -> jax.Array:
    """(b, h, sq, dh) x (b, h, sk, dh) -> (b, h, sq, dh).

    Heads must already be GQA-repeated to match q's head count.
    """
    if not (_use_pallas() or _use_interpret()):
        if k.shape[2] > 1024:     # memory-bounded path for long sequences
            return ref.flash_attention_chunked(
                q, k, v, causal=causal, window=window, softcap=softcap,
                scale=scale, q_offset=q_offset)
        return ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset)
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    bq = min(_fa.DEFAULT_BQ, max(8, sq))
    bk = min(_fa.DEFAULT_BK, max(8, sk))
    qf = _pad_to(q.reshape(b * h, sq, dh), 1, bq)
    kf = _pad_to(k.reshape(b * h, sk, dh), 1, bk)
    vf = _pad_to(v.reshape(b * h, sk, dh), 1, bk)
    out = _fa.flash_attention(
        qf, kf, vf, causal=causal, window=window, softcap=softcap,
        scale=scale, q_offset=q_offset, bq=bq, bk=bk, kv_len=sk,
        interpret=_use_interpret())
    return out[:, :sq].reshape(b, h, sq, dh)
