"""Gathered-candidate distance Pallas kernel with V_delta cache semantics.

During multi-PG construction (FastPGT Alg. 3, mKANNS) each inserted node u
expands frontiers on m graphs; the candidate neighbor vectors are gathered
into (b, k, d) and distances to u are needed — *except* where the shared
V_delta cache already holds them.  The kernel computes

  out[b, i] = mask[b, i] ? delta(u[b], c[b, i]) : cached[b, i]

with delta the metric's distance (kernel form "l2": squared L2; "ip":
1 - <u, c>; cosine = "ip" on pre-normalized inputs — see core/metric.py).

The compute saving on real hardware comes from frontier dedup *before* the
kernel call (fewer rows); the mask keeps bit-exact cache-reuse semantics so
the paper's #dist accounting holds.

Tiling: grid over (b, k/bk); each step holds one query row (1, d) and a
(1, bk, d) candidate slab in VMEM.  Pure VPU work (elementwise + row reduce).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BK = 128


def _gather_dist_kernel(u_ref, c_ref, cached_ref, mask_ref, o_ref, *,
                        kernel: str):
    u = u_ref[...].astype(jnp.float32)                 # (1, d)
    c = c_ref[...].astype(jnp.float32)                 # (1, bk, d)
    if kernel == "ip":
        d2 = 1.0 - jnp.sum(c * u[:, None, :], axis=-1)     # (1, bk)
    else:
        diff = c - u[:, None, :]
        d2 = jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)
    cached = cached_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    o_ref[...] = jnp.where(mask, d2, cached)


@functools.partial(jax.jit, static_argnames=("kernel", "bk", "interpret"))
def gather_distance(
    u: jax.Array,
    c: jax.Array,
    cached: jax.Array,
    mask: jax.Array,
    *,
    kernel: str = "l2",
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """(b, d), (b, k, d), (b, k), (b, k)bool -> (b, k) float32; k % bk == 0."""
    b, d = u.shape
    b2, k, d2 = c.shape
    assert (b, d) == (b2, d2), (u.shape, c.shape)
    assert k % bk == 0, (k, bk)
    grid = (b, k // bk)
    return pl.pallas_call(
        functools.partial(_gather_dist_kernel, kernel=kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(u, c, cached, mask)
