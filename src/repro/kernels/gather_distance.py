"""Gathered-candidate distance Pallas kernel with V_delta cache semantics.

During multi-PG construction (FastPGT Alg. 3, mKANNS) each inserted node u
expands frontiers on m graphs; with width-W multi-expansion (DESIGN.md §10)
the candidate neighbor vectors are gathered into (b, W·Mx, d) slabs and
distances to u are needed — *except* where the shared V_delta cache already
holds them.  The kernel computes

  out[b, i] = mask[b, i] ? delta(u[b], c[b, i]) : cached[b, i]

with delta the metric's distance (kernel form "l2": squared L2; "ip":
1 - <u, c>; cosine = "ip" on pre-normalized inputs — see core/metric.py).

MXU formulation: the cross term is one (bk, d) · (d, 1) dot per tile —
  ip:  1 - <u, c>                          (pure MXU + affine)
  l2:  ‖c‖² - 2·<u, c> + ‖u‖²             (row norms on the VPU)
so the kernel rides the systolic array instead of reducing elementwise on
the VPU like its predecessor; kernels/ref.py remains the semantic oracle
(the l2 norm-expansion matches it to float tolerance, not bit-exactly —
the CPU ops.py dispatch uses the oracle, so host-side results are
unchanged).

The compute saving on real hardware comes from frontier dedup *before* the
kernel call (fewer rows); the mask keeps bit-exact cache-reuse semantics so
the paper's #dist accounting holds.

Tiling: grid over (b, k/bk) slabs; each step holds one query row (1, d) and
a (1, bk, d) candidate slab in VMEM (bk defaults to the 128-lane MXU width;
d is padded to 128 lanes at the ops.py boundary).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BK = 128


def _gather_dist_kernel(u_ref, c_ref, cached_ref, mask_ref, o_ref, *,
                        kernel: str):
    u = u_ref[...].astype(jnp.float32)                 # (1, d)
    c = c_ref[...][0].astype(jnp.float32)              # (bk, d)
    # MXU: (bk, d) @ (d, 1) — the cross term for both kernel forms
    cross = jax.lax.dot_general(
        c, u,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (bk, 1)
    if kernel == "ip":
        d2 = 1.0 - cross[:, 0][None, :]                # (1, bk)
    else:
        cn = jnp.sum(c * c, axis=-1)                   # (bk,)  VPU row norm
        un = jnp.sum(u * u, axis=-1)                   # (1,)
        d2 = jnp.maximum(cn[None, :] - 2.0 * cross[:, 0][None, :] + un,
                         0.0)
    cached = cached_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    o_ref[...] = jnp.where(mask, d2, cached)


@functools.partial(jax.jit, static_argnames=("kernel", "bk", "interpret"))
def gather_distance(
    u: jax.Array,
    c: jax.Array,
    cached: jax.Array,
    mask: jax.Array,
    *,
    kernel: str = "l2",
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """(b, d), (b, k, d), (b, k), (b, k)bool -> (b, k) float32; k % bk == 0."""
    b, d = u.shape
    b2, k, d2 = c.shape
    assert (b, d) == (b2, d2), (u.shape, c.shape)
    assert k % bk == 0, (k, bk)
    grid = (b, k // bk)
    return pl.pallas_call(
        functools.partial(_gather_dist_kernel, kernel=kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(u, c, cached, mask)


def _gather_dist_sq8_kernel(qs_ref, qn_ref, c_ref, cn_ref, cached_ref,
                            mask_ref, o_ref, *, kernel: str):
    """Int8 MXU form of the gather kernel (DESIGN.md §16): candidate slabs
    arrive as int8 codes (4× the VMEM residency of the fp32 slab) and are
    upcast in-register; the query row is pre-scaled by the SQ scale (ADC)
    and ``cn`` carries the precomputed dequantized-row norms, so l2 prices
    exact distances to the dequantized corpus.  Cache semantics unchanged."""
    qs = qs_ref[...].astype(jnp.float32)               # (1, d) q·scale
    c = c_ref[...][0].astype(jnp.float32)              # (bk, d) int8 codes
    # MXU: (bk, d) @ (d, 1) — same contraction as the fp32 form
    cross = jax.lax.dot_general(
        c, qs,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (bk, 1)
    if kernel == "ip":
        d2 = 1.0 - cross[:, 0][None, :]                # (1, bk)
    else:
        qn = qn_ref[...]                               # (1, 1) ‖q‖²
        cn = cn_ref[...]                               # (1, bk) ‖ĉ‖²
        d2 = jnp.maximum((cn + qn) - 2.0 * cross[:, 0][None, :], 0.0)
    cached = cached_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    o_ref[...] = jnp.where(mask, d2, cached)


@functools.partial(jax.jit, static_argnames=("kernel", "bk", "interpret"))
def gather_distance_sq8(
    qs: jax.Array,
    qn: jax.Array,
    codes: jax.Array,
    cn: jax.Array,
    cached: jax.Array,
    mask: jax.Array,
    *,
    kernel: str = "l2",
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Gathered distances against int8 codes; k % bk == 0.

    Shapes: qs (b, d) f32 pre-scaled queries, qn (b, 1) f32 query norms,
    codes (b, k, d) int8, cn (b, k) f32, cached/mask (b, k) -> (b, k) f32.
    """
    b, d = qs.shape
    b2, k, d2 = codes.shape
    assert (b, d) == (b2, d2), (qs.shape, codes.shape)
    assert k % bk == 0, (k, bk)
    grid = (b, k // bk)
    return pl.pallas_call(
        functools.partial(_gather_dist_sq8_kernel, kernel=kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(qs, qn, codes, cn, cached, mask)
