"""Batched serving engine: slot-based continuous batching (lite).

Fixed B decode slots; requests (prompt token arrays) occupy free slots,
prefill fills their KV pages, and one fused decode step advances every
active slot per tick.  Finished sequences (EOS or max-len) free their slot
for the next queued request — the core of continuous batching without the
scheduler bells.  All steps are jit'd once per (B, max_seq).

``RetrievalKnobs`` is the one place the decode-time retrieval-attention
search knobs live (the README "which knob do I turn" table): long-context
deployments construct it once per model and pass ``search_kwargs()``
through to ``serve.retrieval`` so every per-(layer, head) index is searched
with the same serving defaults — hash-set visit state (DESIGN.md §9) and
width-W multi-expansion (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import build as build_lib
from repro.core import graph as graph_lib
from repro.core import metric as metric_lib
from repro.models import model as M
from repro.serve import retrieval as retrieval_lib


@dataclasses.dataclass(frozen=True)
class RetrievalKnobs:
    """Decode-time retrieval-attention serving knobs (one home for the
    defaults the README table documents).

    top_k:        keys attended per decode query.
    ef:           search pool size (recall/#dist trade; must be >= top_k).
    expand_width: frontier nodes expanded per search hop (DESIGN.md §10).
    visited_impl: "hash" = O(ef) search state for any context length;
                  "dense" = exact-#dist instrumentation (DESIGN.md §9).
    block_size:   queries per compiled search shape on the batched path.
    build_impl:   index-construction execution strategy (DESIGN.md §12) —
                  "fused" builds with single-dispatch batch steps
                  (same graphs up to documented ppm-level FP ties, lower
                  host overhead), "per_batch" keeps the host-driven stages.
    num_shards:   corpus partitions (DESIGN.md §11) — a *build-time* knob
                  consumed by ``retrieval.build_index``: > 1 splits the
                  keys over a "shard" mesh axis so no device holds the
                  whole corpus; searches scatter-gather and merge.  The
                  default 1 keeps today's single-device path bit-identical.
    assign:       shard placement policy (DESIGN.md §13, build-time):
                  "chunked" | "random" | "kmeans" — kmeans clusters the
                  keys so centroid routing can skip shards.
    routed_shards: top-p shards searched per decode query (DESIGN.md §13,
                  search-time).  None = scatter-gather over all shards;
                  p < num_shards skips the rest by centroid distance.
    deadline_ms:  per-search latency budget (DESIGN.md §14).  None (the
                  default) disables deadline handling entirely — the
                  healthy path stays bit-identical.  Set, it arms
                  ``serve.resilience.LatencyGovernor``: when the EWMA of
                  observed search latency exceeds the budget, the
                  governor downshifts ef / routed_shards / expand_width
                  along the degradation ladder and recovers with
                  hysteresis once load subsides.  Consumed by the
                  resilience layer, not passed to the search itself
                  (``search_kwargs`` deliberately omits it).
    delta_capacity: streaming-mutation knob (DESIGN.md §15): slots in the
                  fixed-capacity delta layer a ``streaming.MutableIndex``
                  appends inserts into before compaction must fold them
                  into the main graph.  Consumed by the streaming layer
                  (like ``deadline_ms`` by resilience) — deliberately in
                  none of the kwargs dicts below.
    tombstone_compact_frac: tombstoned fraction of the main corpus that
                  triggers background compaction (DESIGN.md §15).  Dead
                  graph nodes still cost search work while never
                  surfacing, so this bounds wasted #dist; streaming-layer
                  knob like ``delta_capacity``.
    quantize:     corpus representation (DESIGN.md §16, build-time —
                  consumed by ``retrieval.build_index``): "none" (default,
                  bit-identical fp32) or "sq8" — store int8 scalar-
                  quantized keys (4× less corpus memory), beam-search the
                  codes and re-rank the final ef-wide pool against fp32
                  keys before the top_k truncation.  The graph build and
                  the tuner's estimation stay fp32 either way.
    """
    top_k: int = 48
    ef: int = 96
    expand_width: int = retrieval_lib.DEFAULT_EXPAND_WIDTH
    visited_impl: str = "hash"
    block_size: int = 64
    build_impl: str = "per_batch"
    num_shards: int = 1
    assign: str = "chunked"
    routed_shards: int | None = None
    deadline_ms: float | None = None
    delta_capacity: int = 1024
    tombstone_compact_frac: float = 0.2
    quantize: str = "none"

    def __post_init__(self):
        if self.top_k > self.ef:
            raise ValueError(
                f"top_k={self.top_k} > ef={self.ef}: the search pool holds "
                f"only ef candidates (see search.knn_search)")
        if self.num_shards < 1:
            raise ValueError(
                f"num_shards must be >= 1, got {self.num_shards}")
        if self.assign not in graph_lib.ASSIGNMENTS:
            raise ValueError(
                f"assign {self.assign!r} not in {graph_lib.ASSIGNMENTS}")
        if self.routed_shards is not None and not (
                1 <= self.routed_shards <= self.num_shards):
            raise ValueError(
                f"routed_shards={self.routed_shards} must be None or in "
                f"[1, num_shards={self.num_shards}] (search.sharded_"
                f"knn_search routes each query to its top-p shards)")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms={self.deadline_ms} must be positive (or None "
                f"to disable the latency governor)")
        if self.delta_capacity < 1:
            raise ValueError(
                f"delta_capacity={self.delta_capacity} must be >= 1: a "
                f"streaming index needs at least one delta slot to accept "
                f"an insert (serve.streaming, DESIGN.md §15)")
        if not 0.0 < self.tombstone_compact_frac <= 1.0:
            raise ValueError(
                f"tombstone_compact_frac={self.tombstone_compact_frac} must "
                f"be in (0, 1]: 0 would trigger compaction on every delete, "
                f"> 1 would never trigger it (serve.streaming, DESIGN.md "
                f"§15)")
        if self.quantize not in metric_lib.QUANTIZE_MODES:
            raise ValueError(
                f"quantize {self.quantize!r} not in "
                f"{metric_lib.QUANTIZE_MODES} (DESIGN.md §16: 'none' = fp32 "
                f"corpus, 'sq8' = int8 search + fp32 re-rank)")
        build_lib.resolve_build_impl(self.build_impl)   # fail fast, not at build

    def search_kwargs(self) -> dict:
        """kwargs for ``retrieval.retrieval_attention`` (single batch)."""
        return dict(top_k=self.top_k, ef=self.ef,
                    expand_width=self.expand_width,
                    visited_impl=self.visited_impl,
                    routed_shards=self.routed_shards)

    def batched_kwargs(self) -> dict:
        """kwargs for ``retrieval.retrieval_attention_batched``."""
        return dict(self.search_kwargs(), block_size=self.block_size)

    def index_kwargs(self) -> dict:
        """Build-time kwargs for ``retrieval.build_index``."""
        return dict(num_shards=self.num_shards, build_impl=self.build_impl,
                    assign=self.assign, quantize=self.quantize)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ArchConfig, *, batch_slots: int = 4,
                 max_seq: int = 512, eos_id: int = -1,
                 greedy: bool = True):
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_seq = max_seq
        self.eos = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.cache = M.init_cache(params, cfg, batch_slots, max_seq)
        self._decode = jax.jit(
            lambda p, tok, cache, pos: M.decode_step(p, cfg, tok, cache, pos))
        self.greedy = greedy
        self.retrieval = None          # ResilientSearcher (attach_retrieval)

    def attach_retrieval(self, index, knobs: RetrievalKnobs | None = None,
                         **resilience_kwargs):
        """Wire a retrieval index into the engine behind the resilience
        layer (serve.resilience, DESIGN.md §14): searches issued through
        ``retrieve`` get shard-health masking, the deadline governor
        (armed by ``knobs.deadline_ms``), and bounded dispatch retry.
        Returns the ResilientSearcher for direct health/plan access."""
        from repro.serve import resilience as resilience_lib
        self.retrieval = resilience_lib.ResilientSearcher(
            index, knobs or RetrievalKnobs(), **resilience_kwargs)
        return self.retrieval

    def retrieve(self, q, **overrides):
        """Resilient retrieval attention for decode queries ``q``."""
        if self.retrieval is None:
            raise ValueError(
                "no retrieval index attached: call attach_retrieval(index) "
                "before retrieve()")
        return self.retrieval.search(q, **overrides)

    def swap_retrieval_index(self, new_index) -> None:
        """Hot-swap the served retrieval index (e.g. one restored via
        serve.resilience.load_index, or a streaming.MutableIndex after
        compaction) without touching engine slots or KV cache.  The
        resilience layer resets shard health AND rebuilds the latency
        governor from its base knobs — rung and EWMA measured the old
        index, so inheriting them would serve the new index with stale
        degraded knobs (see ResilientSearcher.swap_index)."""
        if self.retrieval is None:
            raise ValueError(
                "no retrieval index attached: call attach_retrieval(index) "
                "first — swap replaces an index that is being served")
        self.retrieval.swap_index(new_index)

    def submit(self, req: Request):
        # Reject at submit time, not at admission: _admit's per-token
        # prefill would otherwise advance pos past the KV cache's max_seq
        # pages, silently overwriting live cache rows (the decode loop only
        # checks pos AFTER generating, so an oversized prompt corrupts
        # every slot sharing the cache before the overflow is noticed).
        limit = self.max_seq - 1          # >= 1 position left to decode into
        if len(req.prompt) > limit:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds the "
                f"{limit}-token prefill capacity of this engine "
                f"(max_seq={self.max_seq} KV pages, and decoding needs at "
                f"least one free position); truncate the prompt or build "
                f"the engine with a larger max_seq")
        self.queue.append(req)

    def _admit(self):
        for i in range(self.b):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                # per-slot prefill: feed prompt tokens through decode steps
                # (single-token prefill keeps one compiled program; a chunked
                # prefill path is a straightforward extension)
                for t, tok in enumerate(req.prompt):
                    tokb = np.zeros((self.b, 1), np.int32)
                    tokb[i, 0] = tok
                    logits, self.cache = self._decode(
                        self.params, jnp.asarray(tokb), self.cache,
                        jnp.int32(int(self.pos[i])))
                    self.pos[i] += 1
                req._last_logits = np.asarray(logits)[i, 0]

    def _sample(self, logits_row: np.ndarray) -> int:
        return int(np.argmax(logits_row))

    def step(self) -> int:
        """One engine tick: admit, decode, retire. Returns #active slots."""
        self._admit()
        active = [i for i in range(self.b) if self.slots[i] is not None]
        if not active:
            return 0
        tok = np.zeros((self.b, 1), np.int32)
        for i in active:
            req = self.slots[i]
            nxt = self._sample(req._last_logits)
            req.out.append(nxt)
            tok[i, 0] = nxt
        # NOTE: slots advance with a shared pos scalar per decode call; we
        # issue one decode per distinct slot position group (positions stay
        # aligned for same-tick admissions; mixed groups decode separately).
        groups: dict[int, list[int]] = {}
        for i in active:
            groups.setdefault(int(self.pos[i]), []).append(i)
        for pos, idxs in groups.items():
            tokg = np.zeros((self.b, 1), np.int32)
            for i in idxs:
                tokg[i, 0] = tok[i, 0]
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokg), self.cache, jnp.int32(pos))
            lg = np.asarray(logits)
            for i in idxs:
                self.slots[i]._last_logits = lg[i, 0]
                self.pos[i] += 1
        for i in active:
            req = self.slots[i]
            if (len(req.out) >= req.max_new
                    or (self.eos >= 0 and req.out[-1] == self.eos)
                    or self.pos[i] >= self.max_seq - 1):
                req.done = True
                self.slots[i] = None
        return len(active)

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        while any(s is not None for s in self.slots) or self.queue:
            self.step()
        return requests
