"""Streaming mutable index: crash-consistent online inserts/deletes with
delta-layer search and background compaction (DESIGN.md §15).

``retrieval.build_index`` is build-once: any corpus change forces a full
rebuild, and a crash mid-rebuild leaves nothing to serve.  ``MutableIndex``
wraps the immutable main index with the three mechanisms a mutable serving
corpus needs:

  1. **Delta layer.**  Inserts land in a fixed-capacity append buffer
     (``delta_capacity`` slots — static shapes, so every search program is
     compiled once per configuration).  Searches brute-force the delta with
     the pairwise distance kernel and, once the buffer passes
     ``delta_graph_min`` occupancy, additionally beam-search a small
     incrementally rebuilt Vamana over the delta prefix (rebuilt on
     occupancy doublings — amortized O(log C) rebuilds per fill).  Delta
     candidates fold into the main-graph ef-pool through the same
     ``search._merge_topk`` rank merge the in-loop pool update uses, under
     the existing bit-pinned tie rule (main-pool entries win distance
     ties).

  2. **Tombstone deletes.**  Deleting a main-graph vector records its row
     in an id-keyed tombstone set; ``search.apply_tombstones`` masks those
     rows out of the merged ef-wide pool before the k truncation on every
     execution strategy (unsharded, scatter-gather, routed, fused-routed),
     so a deleted id never surfaces even while its node still anchors
     graph walks.  Deleting a delta vector just kills its slot.

  3. **Write-ahead log + generational snapshots.**  With ``wal_dir`` set,
     every insert/delete is appended as an fsync'd checksummed record
     (``checkpoint.append_framed``) BEFORE it is acknowledged; ``load``
     restores the newest committed snapshot generation and replays the
     WAL, so a process kill at any byte offset recovers exactly the acked
     mutation prefix — a torn final record fails its length/crc frame and
     is refused, never half-applied.  Compaction rolls the generation:
     snapshot + sidecar are written first, the pointer JSON is the atomic
     commit record written LAST, and the old generation's files are
     removed only after the pointer lands (a crash mid-compaction leaves
     the old generation fully intact).

  4. **Background compaction.**  ``maybe_compact`` (and a full delta at
     insert time) rebuilds the affected shards off the search path —
     shards owning tombstones or receiving delta vectors are rebuilt with
     ``build_impl="fused"`` (DESIGN.md §12); untouched shards keep their
     graph arrays byte-for-byte and are restacked through
     ``graph.assemble_sharded`` — then hot-swaps into a running engine via
     ``ResilientSearcher.swap_index`` (which resets the latency governor;
     serving reads the old generation until the swap commits).  Searches
     NEVER rebuild anything: the hot path is search + merge only.

The healthy state (empty delta, no tombstones, pre-compaction) dispatches
``retrieval.retrieval_attention_batched`` on the wrapped index directly —
bit-identical serving through the exact cached programs of a static index
(pinned by tests/test_streaming.py).

External ids are stable across compaction: the wrapped index's rows 0..n-1
become external ids 0..n-1 and inserts continue the sequence; result pools
come back in external-id space.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as graph_lib
from repro.core import metric as metric_lib
from repro.core import search as search_lib
from repro.core import vamana as vamana_lib
from repro.core.graph import INVALID
from repro.kernels import ops
from repro.serve import retrieval as retrieval_lib
from repro.train import checkpoint as ckpt_lib

STREAM_FORMAT = 1
# Streaming runtime artifacts (like resilience's snapshot suffixes): never
# repo content — tools/check_repo.py rejects any tracked file matching
# these suffixes (suffix-sync pinned by tests/test_repo.py).
WAL_SUFFIX = ".wal"
STREAM_STATE = ".stream.npz"
STREAM_POINTER = ".stream.json"
STREAM_SUFFIXES = (WAL_SUFFIX, STREAM_STATE, STREAM_POINTER)

# Delta occupancy at which a small Vamana is built over the delta prefix;
# below it brute force over <= delta_graph_min vectors is cheaper than a
# graph walk (one fused matmul vs ~ef gather rounds).
DELTA_GRAPH_MIN = 128

# Tombstone device arrays pad up to a multiple of this so the set of
# compiled tombstones=True program shapes stays small while deletes accrue.
TOMB_BLOCK_MULT = 16

_OP_INSERT, _OP_DELETE = 1, 2
_INS_HDR = struct.Struct("<BQiI")        # op, seq, ext_id, dim
_DEL_REC = struct.Struct("<BQi")         # op, seq, ext_id


def _encode_insert(seq: int, ext: int, key: np.ndarray,
                   value: np.ndarray) -> bytes:
    return (_INS_HDR.pack(_OP_INSERT, seq, ext, key.size)
            + key.astype(np.float32).tobytes()
            + value.astype(np.float32).tobytes())


def _encode_delete(seq: int, ext: int) -> bytes:
    return _DEL_REC.pack(_OP_DELETE, seq, ext)


def _decode(body: bytes) -> tuple:
    """Decode one WAL record body -> ("insert", seq, ext, key, value) |
    ("delete", seq, ext).  Raises ValueError on any structural mismatch —
    the frame layer already checksummed the bytes, so a failure here means
    a format bug, not a torn write."""
    op = body[0]
    if op == _OP_INSERT:
        _, seq, ext, dim = _INS_HDR.unpack_from(body)
        want = _INS_HDR.size + 2 * 4 * dim
        if len(body) != want:
            raise ValueError(
                f"insert record is {len(body)} bytes, expected {want}")
        vecs = np.frombuffer(body, np.float32, count=2 * dim,
                             offset=_INS_HDR.size)
        return ("insert", seq, ext, vecs[:dim].copy(), vecs[dim:].copy())
    if op == _OP_DELETE:
        _, seq, ext = _DEL_REC.unpack_from(body)
        if len(body) != _DEL_REC.size:
            raise ValueError(
                f"delete record is {len(body)} bytes, expected "
                f"{_DEL_REC.size}")
        return ("delete", seq, ext)
    raise ValueError(f"unknown WAL opcode {op}")


@functools.lru_cache(maxsize=8)
def _delta_brute_fn(kernel: str, kc: int):
    """jit'd delta brute-force: top-kc live slots at offset >= lo.

    ``lo`` (traced) excludes the graph-searched prefix so graph-pool and
    brute candidates stay disjoint (a duplicate id entering ``_merge_topk``
    twice would surface twice).  Returns (slot ids int32[b, kc] INVALID-
    padded, dists, live-slot count int32[] — the per-query #dist the
    brute pass costs).

    Bounded cache: the buffers are *arguments* (never closed over), but
    each cached entry still pins its compiled executable and the jit
    machinery's references; serving sweeps only a handful of (kernel, kc)
    shapes, so 8 entries cover steady state while an adversarial ef sweep
    can no longer grow the cache without bound.
    """

    @jax.jit
    def run(qs, dvecs, live, lo):
        d = ops.pairwise_distance(qs, dvecs, kernel)          # (b, C)
        ok = live & (jnp.arange(dvecs.shape[0]) >= lo)
        d = jnp.where(ok[None, :], d, jnp.inf)
        neg, idx = jax.lax.top_k(-d, kc)     # ties prefer the lower slot
        dist = -neg
        ids = jnp.where(jnp.isfinite(dist), idx.astype(jnp.int32), INVALID)
        dist = jnp.where(ids == INVALID, jnp.inf, dist)
        return ids, dist, jnp.sum(ok).astype(jnp.int32)
    return run


class MutableIndex:
    """A mutable serving index: immutable main graph + delta + tombstones.

    Construct with ``wrap`` (fresh, from a built RetrievalIndex) or
    ``load`` (crash recovery: newest snapshot generation + WAL replay).
    ``attention_batched`` has the calling convention of
    ``retrieval.retrieval_attention_batched``, so a MutableIndex drops
    into ``ResilientSearcher`` / ``ServeEngine.attach_retrieval`` directly
    (the searcher duck-dispatches to it); result pools are in stable
    external-id space.

    Single-writer by design (like ``ServeEngine``'s tick loop): mutations
    and searches interleave on one thread; compaction runs off the search
    path but in-process.
    """

    def __init__(self, index, *, wal_dir: str | None = None,
                 delta_capacity: int = 1024,
                 tombstone_compact_frac: float = 0.2,
                 delta_graph_min: int = DELTA_GRAPH_MIN,
                 build_fn=None, tag: str = "index",
                 main_ext: np.ndarray | None = None,
                 _gen: int = 0, _applied_seq: int = 0,
                 _next_ext: int | None = None):
        if delta_capacity < 1:
            raise ValueError(
                f"delta_capacity={delta_capacity} must be >= 1")
        if not 0.0 < tombstone_compact_frac <= 1.0:
            raise ValueError(
                f"tombstone_compact_frac={tombstone_compact_frac} must be "
                f"in (0, 1]")
        self.main = index
        self._met = metric_lib.resolve(index.metric)
        self.delta_capacity = int(delta_capacity)
        self.tombstone_compact_frac = float(tombstone_compact_frac)
        self.delta_graph_min = int(delta_graph_min)
        self._build = build_fn or self._default_build
        self.wal_dir = wal_dir
        self.tag = tag
        self.gen = int(_gen)
        self.compactions = 0
        n = int(index.keys.shape[0])
        dh = int(index.keys.shape[1])
        self.n_main = n
        self.main_ext = (np.arange(n, dtype=np.int32) if main_ext is None
                         else np.asarray(main_ext, np.int32))
        if self.main_ext.shape != (n,):
            raise ValueError(
                f"main_ext shape {self.main_ext.shape} != ({n},)")
        self._ext_identity = bool(
            np.array_equal(self.main_ext, np.arange(n, dtype=np.int32)))
        self._loc: dict[int, tuple[str, int]] = {
            int(e): ("m", r) for r, e in enumerate(self.main_ext)}
        self._next_ext = (int(self.main_ext.max(initial=-1)) + 1
                          if _next_ext is None else int(_next_ext))
        self._next_seq = int(_applied_seq) + 1
        C = self.delta_capacity
        self._d_keys = np.zeros((C, dh), np.float32)
        self._d_vals = np.zeros((C, dh), np.float32)
        self._d_search = np.zeros((C, dh), np.float32)
        self._d_ext = np.full(C, INVALID, np.int32)
        self._d_live = np.zeros(C, bool)
        self._d_occ = 0
        self._dg_ids = None          # delta-prefix Vamana adjacency (device)
        self._dg_entry = 0
        self._dg_n = 0
        self._tomb_ext: set[int] = set()
        self._tomb_version = 0
        self._tomb_cache: tuple[int, jax.Array | None] = (-1, None)
        self._dirty = True
        self._cat_idx = None
        self._cat_ext_dev = None
        self._main_ext_dev = None
        self._d_search_dev = None
        self._d_live_dev = None

    # -- construction -------------------------------------------------------

    @classmethod
    def wrap(cls, index, **kw) -> "MutableIndex":
        """Wrap a freshly built RetrievalIndex as generation 0.

        With ``wal_dir`` set, persists the generation-0 snapshot and commit
        pointer immediately, so a crash before the first mutation already
        recovers to the wrapped state."""
        mi = cls(index, **kw)
        if mi.wal_dir is not None:
            mi._persist_generation()
        return mi

    @classmethod
    def load(cls, wal_dir: str, *, mesh=None, tag: str = "index",
             **kw) -> "MutableIndex":
        """Crash recovery: newest committed generation + WAL replay.

        Reads the pointer (the atomic commit record — absent or stale
        pointers mean the matching generation never committed), restores
        that generation's index snapshot and external-id sidecar, then
        replays every complete WAL record with ``seq > applied_seq``.  The
        WAL file is truncated to its last complete record, so a torn tail
        is both refused now and physically gone before the next append.
        """
        from repro.serve import resilience as resilience_lib
        ptr_path = os.path.join(wal_dir, tag + STREAM_POINTER)
        if not os.path.exists(ptr_path):
            raise FileNotFoundError(
                f"no stream pointer {ptr_path}: nothing committed here "
                f"(a crash before the first wrap() persists leaves no "
                f"state to recover)")
        with open(ptr_path) as f:
            ptr = json.load(f)
        if ptr.get("format") != STREAM_FORMAT:
            raise ValueError(
                f"stream format {ptr.get('format')!r} != supported "
                f"{STREAM_FORMAT} ({ptr_path})")
        gen = int(ptr["gen"])
        gtag = f"{tag}-g{gen}"
        index = resilience_lib.load_index(wal_dir, tag=gtag, mesh=mesh)
        with np.load(os.path.join(wal_dir, gtag + STREAM_STATE)) as z:
            main_ext = z["main_ext"]
        mi = cls(index, wal_dir=wal_dir, tag=tag, main_ext=main_ext,
                 _gen=gen, _applied_seq=int(ptr["applied_seq"]),
                 _next_ext=int(ptr["next_ext"]), **kw)
        wal_path = mi._wal_path()
        if os.path.exists(wal_path):
            bodies, good = ckpt_lib.read_framed(wal_path)
            expect = int(ptr["applied_seq"]) + 1
            for body in bodies:
                rec = _decode(body)
                if rec[1] != expect:
                    raise ValueError(
                        f"WAL seq {rec[1]} != expected {expect}: the log "
                        f"is not the committed generation's suffix")
                expect += 1
                if rec[0] == "insert":
                    mi._apply_insert(rec[2], rec[3], rec[4])
                else:
                    mi._apply_delete(rec[2])
                mi._next_seq = expect
            with open(wal_path, "rb+") as f:
                f.truncate(good)
        return mi

    # -- properties ---------------------------------------------------------

    @property
    def num_shards(self) -> int:
        return self.main.num_shards

    @property
    def delta_count(self) -> int:
        """Allocated delta slots (live + dead) — the compaction trigger."""
        return self._d_occ

    @property
    def delta_live(self) -> int:
        return int(self._d_live[:self._d_occ].sum())

    @property
    def tombstone_count(self) -> int:
        return len(self._tomb_ext)

    @property
    def tombstone_fraction(self) -> float:
        return len(self._tomb_ext) / max(1, self.n_main)

    @property
    def pristine(self) -> bool:
        """No delta slots and no tombstones: serving can dispatch the
        wrapped index's own cached programs unchanged."""
        return self._d_occ == 0 and not self._tomb_ext

    @property
    def live_count(self) -> int:
        return self.n_main - len(self._tomb_ext) + self.delta_live

    # -- mutation -----------------------------------------------------------

    def insert(self, key, value=None) -> int:
        """Durably insert one vector; returns its stable external id.

        The WAL record is fsync'd BEFORE the in-memory apply — when this
        returns, the insert survives a kill at any later instant.  A full
        delta buffer compacts first (off the search path: searches never
        trigger this).
        """
        key = np.asarray(key, np.float32).reshape(-1)
        dh = self.main.keys.shape[1]
        if key.shape != (dh,):
            raise ValueError(
                f"key shape {key.shape} != ({dh},): one vector per insert")
        value = (key if value is None
                 else np.asarray(value, np.float32).reshape(-1))
        if value.shape != (dh,):
            raise ValueError(f"value shape {value.shape} != ({dh},)")
        if self._d_occ >= self.delta_capacity:
            self.compact()
        ext, seq = self._next_ext, self._next_seq
        if self.wal_dir is not None:
            ckpt_lib.append_framed(self._wal_path(),
                                   _encode_insert(seq, ext, key, value))
        self._apply_insert(ext, key, value)
        self._next_ext = ext + 1
        self._next_seq = seq + 1
        return ext

    def delete(self, ext_id: int) -> None:
        """Durably delete by external id (WAL-first, like ``insert``).

        Main-graph vectors become tombstones — still graph nodes, never
        surfaced (``apply_tombstones`` at merge time); delta vectors just
        lose their slot.  Unknown or already-deleted ids raise KeyError
        before anything is logged.
        """
        ext_id = int(ext_id)
        if ext_id not in self._loc:
            raise KeyError(
                f"external id {ext_id} is not live (never inserted, or "
                f"already deleted)")
        seq = self._next_seq
        if self.wal_dir is not None:
            ckpt_lib.append_framed(self._wal_path(),
                                   _encode_delete(seq, ext_id))
        self._apply_delete(ext_id)
        self._next_seq = seq + 1

    def _apply_insert(self, ext: int, key: np.ndarray,
                      value: np.ndarray) -> None:
        if self._d_occ >= self.delta_capacity:
            raise ValueError(
                f"delta layer full ({self.delta_capacity} slots) — "
                f"compact() first")
        slot = self._d_occ
        self._d_keys[slot] = key
        self._d_vals[slot] = value
        self._d_search[slot] = np.asarray(
            self._met.prepare(jnp.asarray(key[None])))[0]
        self._d_ext[slot] = ext
        self._d_live[slot] = True
        self._d_occ = slot + 1
        self._loc[ext] = ("d", slot)
        self._dirty = True
        if (self._d_occ >= self.delta_graph_min
                and self._d_occ >= 2 * max(1, self._dg_n)):
            self._rebuild_delta_graph(self._d_occ)

    def _apply_delete(self, ext: int) -> None:
        kind, pos = self._loc.pop(ext)
        if kind == "d":
            self._d_live[pos] = False
            self._dirty = True
        else:
            self._tomb_ext.add(ext)
            self._tomb_version += 1

    def _rebuild_delta_graph(self, n: int) -> None:
        """(Re)build the small Vamana over delta slots [0, n).

        Occupancy-doubling schedule: each slot is included in O(log C)
        builds total, so incremental insertion stays amortized-cheap while
        delta search work drops from O(occ) brute distances to a graph
        walk + an O(occ - n) brute tail.  Dead slots stay nodes (masked at
        candidate time), mirroring the main graph's tombstone treatment.
        """
        params = self.main.params.clamped(n)
        res = vamana_lib.build_vamana(
            jnp.asarray(self._d_search[:n]), params,
            metric=self._met.kernel, build_impl="fused")
        self._dg_ids = res.g.ids[0]
        self._dg_entry = int(res.entry)
        self._dg_n = n

    # -- search -------------------------------------------------------------

    def _tomb_rows_device(self) -> jax.Array | None:
        """Tombstoned MAIN ROWS as a bucketed INVALID-padded device array
        (None when empty — the search then dispatches the healthy
        ``tombstones=False`` cached program)."""
        if not self._tomb_ext:
            return None
        ver, cached = self._tomb_cache
        if ver == self._tomb_version:
            return cached
        ext2row = {int(e): r for r, e in enumerate(self.main_ext)}
        rows = np.sort(np.fromiter(
            (ext2row[e] for e in self._tomb_ext), np.int32,
            count=len(self._tomb_ext)))
        width = graph_lib.bucket(rows.size, TOMB_BLOCK_MULT)
        padded = np.full(width, INVALID, np.int32)
        padded[:rows.size] = rows
        dev = jnp.asarray(padded)
        self._tomb_cache = (self._tomb_version, dev)
        return dev

    def _sync_delta(self) -> None:
        """Push host delta buffers to their device mirrors (lazily, once
        per mutation batch — searches between mutations pay nothing)."""
        if not self._dirty:
            return
        self._d_search_dev = jnp.asarray(self._d_search)
        self._d_live_dev = jnp.asarray(self._d_live)
        self._cat_idx = dataclasses.replace(
            self.main,
            keys=jnp.concatenate(
                [self.main.keys, jnp.asarray(self._d_keys)], axis=0),
            values=jnp.concatenate(
                [self.main.values, jnp.asarray(self._d_vals)], axis=0))
        self._cat_ext_dev = jnp.asarray(
            np.concatenate([self.main_ext, self._d_ext]))
        self._dirty = False

    def _ext_ids(self, pool_ids: jax.Array, table: jax.Array) -> jax.Array:
        return jnp.where(pool_ids == INVALID, INVALID,
                         table[jnp.maximum(pool_ids, 0)])

    def _delta_candidates(self, qb: jax.Array, row_mask: jax.Array,
                          ef: int, visited_impl: str, expand_width: int):
        """Delta-layer candidates for one query block.

        Returns (slot ids, dists, extra #dist for the block, extra hops).
        Graph mode searches the Vamana prefix then brute-forces the tail;
        the two slot ranges are disjoint, so their concatenation enters
        ``_merge_topk`` duplicate-free.  Dead slots mask to INVALID/inf
        here — the delta needs no tombstone array, its liveness bitmap IS
        the mask.
        """
        kc = min(ef, self.delta_capacity)
        brute = _delta_brute_fn(self._met.kernel, kc)
        nrows = int(jnp.sum(row_mask)) if row_mask is not None else \
            qb.shape[0]
        ids_b, dist_b, n_live_tail = brute(
            qb, self._d_search_dev, self._d_live_dev,
            jnp.int32(self._dg_n))
        n_extra = int(n_live_tail) * nrows
        hops = jnp.int32(0)
        if self._dg_n == 0:
            return ids_b, dist_b, n_extra, hops
        efd = min(ef, self._dg_n)
        res = search_lib.knn_search(
            self._dg_ids, self._d_search_dev[:self._dg_n], qb,
            efd, efd, self._dg_entry, metric=self._met.kernel,
            visited_impl=visited_impl, expand_width=expand_width,
            row_mask=row_mask)
        alive = (res.pool_ids != INVALID) & \
            self._d_live_dev[jnp.maximum(res.pool_ids, 0)]
        ids_g = jnp.where(alive, res.pool_ids, INVALID)
        dist_g = jnp.where(alive, res.pool_dist, jnp.inf)
        return (jnp.concatenate([ids_g, ids_b], axis=-1),
                jnp.concatenate([dist_g, dist_b], axis=-1),
                n_extra + int(res.n_computed), res.hops)

    def attention_batched(self, q: jax.Array, *, top_k: int, ef: int,
                          scale: float | None = None, block_size: int = 64,
                          visited_impl: str = "hash",
                          expand_width: int =
                          retrieval_lib.DEFAULT_EXPAND_WIDTH,
                          routed_shards: int | None = None,
                          shard_mask=None):
        """Batched retrieval attention over main ∪ delta − tombstones.

        Calling convention of ``retrieval.retrieval_attention_batched``
        (so ``ResilientSearcher`` dispatches here unchanged); result pool
        ids are EXTERNAL ids.  Pristine state short-circuits to the
        wrapped index's own batched path — bit-identical serving.  Else,
        per block: the main index searches with a FULL ef-wide pool (plus
        the tombstone mask at its merge fold), delta candidates fold in
        through ``_merge_topk`` (main pool wins distance ties — the
        bit-pinned rule), and only then is the pool truncated to top_k, so
        the ef − k slack refills what tombstones evict.
        """
        if self.pristine:
            out, res = retrieval_lib.retrieval_attention_batched(
                self.main, q, top_k=top_k, ef=ef, scale=scale,
                block_size=block_size, visited_impl=visited_impl,
                expand_width=expand_width, routed_shards=routed_shards,
                shard_mask=shard_mask)
            if self._ext_identity:
                return out, res
            if self._main_ext_dev is None:
                self._main_ext_dev = jnp.asarray(self.main_ext)
            return out, res._replace(
                pool_ids=self._ext_ids(res.pool_ids, self._main_ext_dev))
        B, dh = q.shape
        if B == 0:
            raise ValueError("empty query batch")
        self._sync_delta()
        tomb = self._tomb_rows_device()
        qs_all = self._met.prepare(q)
        bs = graph_lib.bucket(min(block_size, B), 16)
        pool_ids, pool_dist, n_fresh, n_comp, hop_cnt = [], [], [], [], []
        extra_dist = 0
        res = None
        for off in range(0, B, bs):
            nrows = min(bs, B - off)
            qb = jnp.zeros((bs, dh), qs_all.dtype).at[:nrows].set(
                qs_all[off:off + nrows])
            rmask = jnp.arange(bs) < nrows
            res = retrieval_lib._search_index(
                self.main, qb, ef, ef, visited_impl, expand_width,
                row_mask=rmask, routed_shards=routed_shards,
                shard_mask=shard_mask, tombstone_ids=tomb)
            pi, pd = res.pool_ids, res.pool_dist
            if self._d_occ:
                dids, ddist, n_extra, dhops = self._delta_candidates(
                    qb, rmask, ef, visited_impl, expand_width)
                cand = jnp.where(dids == INVALID, INVALID,
                                 dids + self.n_main)
                pi, pd, _ = search_lib._merge_topk(
                    pi, pd, jnp.zeros_like(pi, bool), cand, ddist)
                extra_dist += n_extra
                hop_cnt.append(dhops)
            pool_ids.append(pi[:nrows, :top_k])
            pool_dist.append(pd[:nrows, :top_k])
            n_fresh.append(res.n_fresh)
            n_comp.append(res.n_computed)
            hop_cnt.append(res.hops)
        ids = jnp.concatenate(pool_ids, axis=0)
        agg = search_lib.SearchResult(
            self._ext_ids(ids, self._cat_ext_dev),
            jnp.concatenate(pool_dist, axis=0),
            jnp.sum(jnp.stack(n_fresh)) + extra_dist,
            jnp.sum(jnp.stack(n_comp)) + extra_dist,
            jnp.max(jnp.stack(hop_cnt)), res.cache_d, res.cache_has)
        out = retrieval_lib._attend(self._cat_idx, q, ids, scale)
        return out, agg

    def knn(self, q: jax.Array, k: int, ef: int, **kw):
        """Plain k-ANNS over the mutable corpus: (ext ids, dists)."""
        _, res = self.attention_batched(q, top_k=k, ef=ef, **kw)
        return res.pool_ids, res.pool_dist

    # -- compaction ---------------------------------------------------------

    def maybe_compact(self, searcher=None) -> bool:
        """Compact when the delta is full or the tombstone fraction
        crosses ``tombstone_compact_frac``; hot-swaps into ``searcher``
        (``ResilientSearcher.swap_index``) when given.  Returns whether a
        compaction ran.  This is the off-path trigger a serving loop calls
        between requests — searches themselves never compact."""
        if (self._d_occ < self.delta_capacity
                and self.tombstone_fraction < self.tombstone_compact_frac):
            return False
        self.compact(searcher=searcher)
        return True

    def compact(self, *, searcher=None) -> None:
        """Fold delta + tombstones into a new main generation.

        Unsharded: one fused rebuild over the live vectors.  Sharded: only
        AFFECTED shards rebuild — a shard is affected iff it owns a
        tombstoned row or receives a delta vector (nearest centroid, the
        same routing statistic searches use); untouched shards keep their
        adjacency/data arrays byte-for-byte, with only their global ids
        renumbered into the compacted row space, and everything restacks
        through ``graph.assemble_sharded``.  With ``wal_dir``, the new
        generation persists snapshot-first, pointer-last (the atomic
        commit), then the old generation's files are removed — a crash
        anywhere before the pointer lands recovers the OLD generation plus
        its complete WAL.  Serving reads the old index object until
        ``searcher.swap_index`` commits the new one.
        """
        main = self.main
        live_mask = np.ones(self.n_main, bool)
        if self._tomb_ext:
            ext2row = {int(e): r for r, e in enumerate(self.main_ext)}
            for e in self._tomb_ext:
                live_mask[ext2row[e]] = False
        live_rows = np.nonzero(live_mask)[0]
        d_slots = np.nonzero(self._d_live[:self._d_occ])[0]
        keys_np = np.asarray(main.keys)
        vals_np = np.asarray(main.values)
        new_keys = np.concatenate([keys_np[live_rows],
                                   self._d_keys[d_slots]])
        new_vals = np.concatenate([vals_np[live_rows],
                                   self._d_vals[d_slots]])
        new_ext = np.concatenate([self.main_ext[live_rows],
                                  self._d_ext[d_slots]])
        n_new = new_keys.shape[0]
        if n_new < 2:
            raise ValueError(
                f"refusing to compact down to {n_new} vectors: a graph "
                f"needs at least 2 nodes")
        new_search = np.asarray(self._met.prepare(jnp.asarray(new_keys)))
        prov = dict(main.provenance or {})
        prov["build_impl"] = "fused"
        if main.shards is None:
            lids, entry = self._build(new_search)
            search_dev = jnp.asarray(new_search)
            # The new generation inherits the main index's quantization
            # mode; codes are recomputed over the compacted corpus (scale
            # shifts as rows churn — stale codes would skew distances).
            quant = (metric_lib.quantize_sq8(search_dev)
                     if main.quantize == "sq8" else None)
            new_main = retrieval_lib.RetrievalIndex(
                graph_ids=jnp.asarray(lids), keys=jnp.asarray(new_keys),
                values=jnp.asarray(new_vals),
                search_keys=search_dev, entry=int(entry),
                params=main.params, metric=main.metric, provenance=prov,
                quantize=main.quantize, quant=quant)
        else:
            new_main = self._compact_sharded(
                main, live_mask, live_rows, d_slots, new_keys, new_vals,
                new_search, prov)
        self.main = new_main
        self.n_main = n_new
        self.main_ext = np.asarray(new_ext, np.int32)
        self._ext_identity = bool(np.array_equal(
            self.main_ext, np.arange(n_new, dtype=np.int32)))
        self._loc = {int(e): ("m", r)
                     for r, e in enumerate(self.main_ext)}
        self._d_ext[:] = INVALID
        self._d_live[:] = False
        self._d_occ = 0
        self._dg_ids, self._dg_n = None, 0
        self._tomb_ext = set()
        self._tomb_version += 1
        self._dirty = True
        self._main_ext_dev = None
        # Release the OLD generation's corpus-sized device buffers now.
        # ``_dirty`` alone is not enough: a post-compact index with an
        # empty delta is *pristine*, so ``attention_batched`` short-
        # circuits to the main path and ``_sync_delta`` never runs to
        # replace these mirrors — they would pin the old keys/values/
        # search arrays on device for the life of the process.
        self._cat_idx = None
        self._cat_ext_dev = None
        self._d_search_dev = None
        self._d_live_dev = None
        self._tomb_cache = (-1, None)
        self.gen += 1
        self.compactions += 1
        if self.wal_dir is not None:
            self._persist_generation()
        if searcher is not None:
            searcher.swap_index(self)

    def _compact_sharded(self, main, live_mask, live_rows, d_slots,
                         new_keys, new_vals, new_search, prov):
        """Affected-shard rebuild + restack (see ``compact``)."""
        sg = main.shards
        S = sg.num_shards
        old2new = np.full(self.n_main, INVALID, np.int64)
        old2new[live_rows] = np.arange(live_rows.size)
        # Route each live delta vector to its nearest live centroid.
        assign: list[list[int]] = [[] for _ in range(S)]
        if d_slots.size:
            dprep = jnp.asarray(self._d_search[d_slots])
            scores = np.asarray(metric_lib.kernel_distance(
                dprep[:, None, :], sg.centroids[None, :, :],
                self._met.kernel))
            for j, s in enumerate(np.argmin(scores, axis=-1)):
                # new row of delta slot d_slots[j]
                assign[int(s)].append(live_rows.size + j)
        gids_np = np.asarray(sg.global_ids)
        counts_np = np.asarray(sg.counts)
        ids_parts, data_parts, gid_parts, entries = [], [], [], []
        for s in range(S):
            c = int(counts_np[s])
            members = gids_np[s, :c]
            keep = live_mask[members]
            new_members = old2new[members[keep]].astype(np.int32)
            adds = np.asarray(assign[s], np.int32)
            if keep.all() and adds.size == 0:
                # Untouched: graph + local vectors byte-identical, only
                # the global-id renumbering changes.
                ids_parts.append(np.asarray(sg.ids[s, :c]))
                data_parts.append(np.asarray(sg.data[s, :c]))
                gid_parts.append(new_members)
                entries.append(int(sg.entries[s]))
                continue
            rows = np.concatenate([new_members, adds])
            if rows.size == 0:
                raise ValueError(
                    f"compaction would empty shard {s}: every member is "
                    f"tombstoned and no delta vector routes there — "
                    f"repartition (build_index) instead of compacting")
            local = new_search[rows]
            lids, entry = self._build(local)
            ids_parts.append(np.asarray(lids))
            data_parts.append(local)
            gid_parts.append(rows)
            entries.append(int(entry))
        mesh = getattr(getattr(sg.ids, "sharding", None), "mesh", None)
        shards = graph_lib.assemble_sharded(
            ids_parts, data_parts, gid_parts, entries,
            centroids=np.asarray(sg.centroids), mesh=mesh)
        if main.quantize == "sq8":
            # Re-quantize over the compacted shard stack (global scale
            # shifts as rows churn); untouched shards keep their fp32
            # data byte-identical, only the side-car codes refresh.
            shards = graph_lib.quantize_sharded(
                shards, metric=self._met.kernel, mesh=mesh)
        entry = int(shards.global_ids[0][int(shards.entries[0])])
        return retrieval_lib.RetrievalIndex(
            graph_ids=None, keys=jnp.asarray(new_keys),
            values=jnp.asarray(new_vals), search_keys=None, entry=entry,
            params=main.params, metric=main.metric, shards=shards,
            provenance=prov, quantize=main.quantize)

    def _default_build(self, local):
        """Compaction build hook: fused Vamana with the main params
        (clamped to the piece being rebuilt).  Overridable via
        ``build_fn`` — benches inject cheap random graphs the way
        kernel_microbench does for its shard graphs."""
        prov = self.main.provenance or {}
        res = vamana_lib.build_vamana(
            jnp.asarray(local),
            self.main.params.clamped(int(local.shape[0])),
            seed=int(prov.get("seed", 0)),
            batch_size=int(prov.get("batch_size", 256)),
            metric=self._met.kernel, build_impl="fused")
        return res.g.ids[0], res.entry

    # -- persistence --------------------------------------------------------

    def _wal_path(self) -> str:
        return os.path.join(self.wal_dir,
                            f"{self.tag}-g{self.gen}{WAL_SUFFIX}")

    def _persist_generation(self) -> None:
        """Commit the current generation: snapshot + sidecar first, fresh
        WAL truncated, pointer JSON LAST (the atomic commit record), old
        generation removed only after the pointer lands."""
        from repro.serve import resilience as resilience_lib
        gtag = f"{self.tag}-g{self.gen}"
        resilience_lib.save_index(self.main, self.wal_dir, tag=gtag)
        ckpt_lib.atomic_write_npz(
            os.path.join(self.wal_dir, gtag + STREAM_STATE),
            {"main_ext": self.main_ext})
        # An orphaned WAL at this generation number (a compaction that
        # crashed after writing files but before committing the pointer,
        # then recovered at the old generation) must not resurface.
        wal = self._wal_path()
        if os.path.exists(wal):
            os.unlink(wal)
        ckpt_lib.atomic_write_json(
            os.path.join(self.wal_dir, self.tag + STREAM_POINTER),
            {"format": STREAM_FORMAT, "gen": self.gen, "tag": self.tag,
             "applied_seq": self._next_seq - 1,
             "next_ext": self._next_ext})
        prev = self.gen - 1
        if prev >= 0:
            ptag = f"{self.tag}-g{prev}"
            for name in (ptag + resilience_lib.SNAPSHOT_NPZ,
                         ptag + resilience_lib.SNAPSHOT_MANIFEST,
                         ptag + STREAM_STATE, ptag + WAL_SUFFIX):
                p = os.path.join(self.wal_dir, name)
                if os.path.exists(p):
                    os.unlink(p)
