"""Serving resilience: shard health, fault injection, degradation, snapshots.

The serving counterpart of ``train/fault_tolerance.py`` (DESIGN.md §14).
PR 5/7 made the corpus shard-resident across a device mesh, which turned
one dead or slow shard into a whole-search outage; this module gives the
serving stack the three mechanisms production systems use against that:

  1. **Shard health + fault injection.**  ``ShardHealth`` tracks a
     per-shard liveness mask and injected delays; its ``mask()`` feeds the
     ``shard_mask`` parameter threaded through ``sharded_knn_search`` and
     both routed execution strategies (DESIGN.md §14 has the counter and
     merge contract).  ``FaultPlan`` is the injection harness — kill,
     revive, delay, or corrupt a shard at a scheduled search call — usable
     identically from tests and benches, so degraded-mode behaviour is
     pinned, not guessed.

  2. **Deadline-aware graceful degradation.**  ``LatencyGovernor`` tracks
     an EWMA of per-call search latency against ``RetrievalKnobs``'
     ``deadline_ms`` budget and walks a precomputed knob ladder
     (``degradation_ladder``: halve ``ef`` toward ``top_k``, then shed
     ``routed_shards`` toward 1, then halve ``expand_width``) — downshift
     immediately on overload, recover one rung only after ``patience``
     consecutive calls under ``recover_frac`` of budget (hysteresis, so a
     noisy boundary doesn't thrash compile caches).  ``search_with_retry``
     adds bounded retry-with-backoff for transient dispatch failures.

  3. **Index snapshot / restore.**  ``save_index`` / ``load_index``
     serialize a ``RetrievalIndex`` (sharded or not) with the
     write-temp-then-rename idiom of ``train/checkpoint.py`` (shared
     helpers ``atomic_write_npz`` / ``atomic_write_json``): the manifest
     sidecar is written *after* the array archive, so a torn writer leaves
     no manifest and the loader refuses — readers only ever see complete
     snapshots.  Restore ``device_put``s the shards back onto the
     ``"shard"`` mesh (``graph.place_sharded``), so a restored index
     serves bit-identical results through the same cached programs.

``ResilientSearcher`` composes all three around
``retrieval.retrieval_attention_batched`` and is what
``ServeEngine.attach_retrieval`` runs; ``swap_index`` hot-swaps a restored
(or freshly rebuilt) index between calls without dropping engine state.
This module deliberately does not import ``serve.engine`` — the engine
imports it, never the reverse.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.core import graph as graph_lib
from repro.core import metric as metric_lib
from repro.core import vamana as vamana_lib
from repro.serve import retrieval as retrieval_lib
from repro.train import checkpoint as ckpt_lib

SNAPSHOT_FORMAT = 1
# Snapshot artifacts are runtime state, never repo content: tools/
# check_repo.py rejects any tracked file matching these suffixes.
SNAPSHOT_NPZ = ".snapshot.npz"
SNAPSHOT_MANIFEST = ".snapshot.json"


# ---------------------------------------------------------------------------
# Shard health + fault injection.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardHealth:
    """Per-shard liveness + injected-delay state for one sharded index.

    ``alive`` is the authoritative liveness mask — ``mask()`` hands it to
    ``sharded_knn_search(shard_mask=...)``, which excludes dead shards
    from routing, merge, and counters (DESIGN.md §14).  ``delays_s``
    models slow-but-alive shards: the searcher stalls by the worst live
    delay per call, which is exactly how a straggling shard shows up in
    scatter-gather latency (the merge waits for the slowest pool).
    """
    alive: np.ndarray            # bool[S]
    delays_s: np.ndarray         # float64[S] injected per-call stall

    @classmethod
    def fresh(cls, num_shards: int) -> "ShardHealth":
        return cls(alive=np.ones(num_shards, bool),
                   delays_s=np.zeros(num_shards, np.float64))

    @property
    def num_shards(self) -> int:
        return self.alive.shape[0]

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    def kill(self, shard: int) -> None:
        self.alive[shard] = False

    def revive(self, shard: int) -> None:
        self.alive[shard] = True
        self.delays_s[shard] = 0.0

    def delay(self, shard: int, seconds: float) -> None:
        self.delays_s[shard] = float(seconds)

    def mask(self) -> np.ndarray | None:
        """``shard_mask`` argument for the search: None while all-alive
        (the healthy path stays the bit-identical no-mask program)."""
        return None if self.alive.all() else self.alive.copy()

    def live_delay(self) -> float:
        """Worst injected stall among live shards (the merge's critical
        path); dead shards don't stall anyone — they are routed around."""
        live = self.delays_s[self.alive]
        return float(live.max()) if live.size else 0.0


FAULT_KINDS = ("kill", "revive", "delay", "corrupt", "crash")


class InjectedCrash(Exception):
    """A ``FaultPlan`` "crash" fault fired: the process is (simulated) dead.

    Deliberately NOT a ``RuntimeError``, so ``search_with_retry`` never
    retries it — a crash is not a transient dispatch failure; the harness
    that injected it catches this, abandons all in-memory state, and
    recovers from disk (``streaming.MutableIndex.load`` replays the WAL,
    DESIGN.md §15), exactly like a fresh process after a kill -9.
    """


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: applied when the searcher reaches ``at_call``.

    kind:     "kill" | "revive" | "delay" | "corrupt" | "crash"
              (FAULT_KINDS).  "crash" is process-level, not per-shard:
              ``shard`` is ignored and ``FaultPlan.apply`` raises
              ``InjectedCrash`` — the recovery path, not the mask, is
              what's under test.
    shard:    target shard id ("crash" ignores it).
    at_call:  0-based search-call index the fault fires at.
    seconds:  injected per-call stall ("delay" only; 0 clears).
    rows:     adjacency rows to scramble ("corrupt" only).
    seed:     corruption RNG seed ("corrupt" only — deterministic chaos).
    """
    kind: str
    shard: int
    at_call: int
    seconds: float = 0.0
    rows: int = 8
    seed: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {FAULT_KINDS}")


@dataclasses.dataclass
class FaultPlan:
    """A seeded, replayable schedule of faults (the injection harness).

    ``apply(call_idx, health, index)`` fires every fault scheduled at
    ``call_idx`` against the health mask (kill/revive/delay) or the index
    itself (corrupt — returns a REPLACEMENT index; adjacency corruption
    must go through the functional update because device arrays are
    immutable).  Tests and benches drive the same plan object, so a
    degraded-mode bench row and its guarding test inject literally the
    same failure.
    """
    faults: list[Fault] = dataclasses.field(default_factory=list)

    def apply(self, call_idx: int, health: ShardHealth,
              index: "retrieval_lib.RetrievalIndex | None" = None):
        """Fire faults due at ``call_idx``; returns the (maybe new) index."""
        for f in self.faults:
            if f.at_call != call_idx:
                continue
            if f.kind == "crash":
                raise InjectedCrash(
                    f"injected crash at call {call_idx}: recover from disk "
                    f"(WAL replay), not from this process's memory")
            if not 0 <= f.shard < health.num_shards:
                raise ValueError(
                    f"fault targets shard {f.shard} but the index has "
                    f"{health.num_shards} shards")
            if f.kind == "kill":
                health.kill(f.shard)
            elif f.kind == "revive":
                health.revive(f.shard)
            elif f.kind == "delay":
                health.delay(f.shard, f.seconds)
            elif f.kind == "corrupt":
                if index is None or index.shards is None:
                    raise ValueError(
                        "corrupt fault needs a sharded RetrievalIndex")
                index = dataclasses.replace(
                    index, shards=corrupt_shard(index.shards, f.shard,
                                                rows=f.rows, seed=f.seed))
        return index


def corrupt_shard(sg: graph_lib.ShardedGraph, shard: int, *, rows: int = 8,
                  seed: int = 0) -> graph_lib.ShardedGraph:
    """Scramble ``rows`` adjacency rows of one shard (silent data damage).

    Each victim row's out-neighbors are replaced with uniform-random
    *valid local ids of the same shard* — the graph stays structurally
    legal (no out-of-range gathers, no crash), but the navigability of the
    damaged region is destroyed, which is the failure mode bit-rot or a
    partial write produces in practice.  ``flat_ids`` is recomputed so
    both execution strategies see the same damage; the result is placed
    back onto the mesh the input lived on.  Deterministic in ``seed``.
    """
    ids = np.array(sg.ids)                                 # (S, n_s, Mx)
    S, n_s, mx = ids.shape
    if not 0 <= shard < S:
        raise ValueError(f"shard {shard} out of range [0, {S})")
    count = int(sg.counts[shard])
    rng = np.random.default_rng(seed)
    victims = rng.choice(count, size=min(rows, count), replace=False)
    ids[shard, victims] = rng.integers(
        0, count, size=(victims.size, mx)).astype(np.int32)
    offs = (np.arange(S, dtype=np.int32) * n_s)[:, None, None]
    flat = np.where(ids >= 0, ids + offs, graph_lib.INVALID).reshape(-1, mx)
    mesh = getattr(getattr(sg.ids, "sharding", None), "mesh", None)
    return graph_lib.place_sharded(
        dataclasses.replace(sg, ids=ids, flat_ids=flat), mesh=mesh)


# ---------------------------------------------------------------------------
# Deadline-aware graceful degradation.
# ---------------------------------------------------------------------------

def degradation_ladder(base) -> list:
    """Precompute the stepwise knob downshifts from ``base`` knobs.

    Rung 0 is ``base`` untouched (the healthy program — bit-identical
    serving while the budget holds).  Each further rung sheds work in
    recall-cheapest-first order (DESIGN.md §14):

      1. halve ``ef`` until it floors at ``top_k`` (pool depth is the
         biggest #dist lever and the shallowest recall cliff),
      2. shed ``routed_shards`` toward 1 (sharded indexes only; each halving
         cuts distance work ~2x at the clustered-corpus recall cost §13
         bounds),
      3. halve ``expand_width`` to 1 (last: it trades latency per hop, not
         work, so it only helps once pools are already minimal).

    Rungs are full knob objects (``dataclasses.replace``), so every rung
    hits an lru-cached search program after its first compile — the ladder
    is a set of precompilable operating points, not a continuous dial.
    """
    ladder = [base]
    cur = base
    while cur.ef > base.top_k:
        cur = dataclasses.replace(cur, ef=max(base.top_k, cur.ef // 2))
        ladder.append(cur)
    if cur.num_shards > 1:
        p = cur.routed_shards or cur.num_shards
        while p > 1:
            p = max(1, p // 2)
            cur = dataclasses.replace(cur, routed_shards=p)
            ladder.append(cur)
    while cur.expand_width > 1:
        cur = dataclasses.replace(
            cur, expand_width=max(1, cur.expand_width // 2))
        ladder.append(cur)
    return ladder


class LatencyGovernor:
    """EWMA latency vs budget -> a rung on the degradation ladder.

    Downshift is immediate (one rung per over-budget observation — an
    overloaded engine must shed now); recovery is hysteresis-guarded: one
    rung up only after ``patience`` consecutive observations below
    ``recover_frac`` x budget, and the patience counter resets on any
    non-qualifying tick.  With no budget (``deadline_ms=None``) the
    governor is inert and always returns rung 0.
    """

    def __init__(self, knobs, *, alpha: float = 0.3,
                 recover_frac: float = 0.5, patience: int = 3):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha={alpha} must be in (0, 1]")
        if not 0.0 < recover_frac < 1.0:
            raise ValueError(
                f"recover_frac={recover_frac} must be in (0, 1): recovery "
                f"must require real headroom below the budget, or the "
                f"governor oscillates on the boundary")
        self.base = knobs
        self.ladder = degradation_ladder(knobs)
        self.budget_s = (None if getattr(knobs, "deadline_ms", None) is None
                         else knobs.deadline_ms / 1e3)
        self.alpha = alpha
        self.recover_frac = recover_frac
        self.patience = patience
        self.level = 0
        self.ewma_s: float | None = None
        self._calm = 0

    @property
    def knobs(self):
        return self.ladder[self.level]

    def observe(self, latency_s: float):
        """Fold one search latency in; returns the knobs for the NEXT call."""
        self.ewma_s = (latency_s if self.ewma_s is None else
                       self.alpha * latency_s
                       + (1.0 - self.alpha) * self.ewma_s)
        if self.budget_s is None:
            return self.knobs
        if self.ewma_s > self.budget_s:
            if self.level < len(self.ladder) - 1:
                self.level += 1
            self._calm = 0
        elif self.ewma_s < self.recover_frac * self.budget_s:
            self._calm += 1
            if self._calm >= self.patience and self.level > 0:
                self.level -= 1
                self._calm = 0
        else:
            self._calm = 0
        return self.knobs


def search_with_retry(fn, *args, retries: int = 2, backoff_s: float = 0.05,
                      retriable: tuple = (RuntimeError,), sleep=time.sleep,
                      **kwargs):
    """Call ``fn`` with bounded retry + exponential backoff.

    Covers *transient* dispatch failure (device OOM races, interconnect
    hiccups — they surface as ``RuntimeError`` / XlaRuntimeError from the
    jax dispatch layer); programming errors (``ValueError`` validation)
    are never retried.  ``retries`` is the number of RE-tries: the call
    runs at most ``retries + 1`` times, backoff doubling each attempt.
    The last failure re-raises unchanged.
    """
    if retries < 0:
        raise ValueError(f"retries={retries} must be >= 0")
    for attempt in range(retries + 1):
        try:
            return fn(*args, **kwargs)
        except retriable:
            if attempt == retries:
                raise
            sleep(backoff_s * (2 ** attempt))


# ---------------------------------------------------------------------------
# Index snapshot / restore.
# ---------------------------------------------------------------------------

def _snapshot_paths(snap_dir: str, tag: str) -> tuple[str, str]:
    return (os.path.join(snap_dir, tag + SNAPSHOT_NPZ),
            os.path.join(snap_dir, tag + SNAPSHOT_MANIFEST))


def save_index(idx: retrieval_lib.RetrievalIndex, snap_dir: str,
               tag: str = "index") -> str:
    """Atomically snapshot a RetrievalIndex; returns the manifest path.

    Two files in ``snap_dir``: ``<tag>.snapshot.npz`` (every array, host
    np) and ``<tag>.snapshot.json`` (the manifest: format version, metric,
    entry, Vamana params, shard count, build provenance, array inventory).
    Both go through the atomic write-temp-then-rename helpers of
    ``train/checkpoint.py``, and the manifest is written AFTER the
    archive: a writer killed mid-snapshot leaves at worst an npz with no
    manifest, which ``load_index`` treats as absent — the previous
    complete snapshot (same tag) survives the overwrite untouched.
    """
    arrays: dict[str, np.ndarray] = {
        "keys": np.asarray(idx.keys),
        "values": np.asarray(idx.values),
    }
    if idx.graph_ids is not None:
        arrays["graph_ids"] = np.asarray(idx.graph_ids)
    if idx.search_keys is not None:
        arrays["search_keys"] = np.asarray(idx.search_keys)
    if idx.shards is not None:
        sg = idx.shards
        arrays["shards/ids"] = np.asarray(sg.ids)
        arrays["shards/data"] = np.asarray(sg.data)
        arrays["shards/global_ids"] = np.asarray(sg.global_ids)
        arrays["shards/entries"] = np.asarray(sg.entries)
        arrays["shards/counts"] = np.asarray(sg.counts)
        if sg.centroids is not None:
            arrays["shards/centroids"] = np.asarray(sg.centroids)
        if sg.flat_ids is not None:
            arrays["shards/flat_ids"] = np.asarray(sg.flat_ids)
        if sg.qcodes is not None:
            arrays["shards/qcodes"] = np.asarray(sg.qcodes)
            arrays["shards/qscale"] = np.asarray(sg.qscale)
            arrays["shards/qnorms"] = np.asarray(sg.qnorms)
    if idx.quant is not None:
        # Unsharded SQ8 state (DESIGN.md §16): codes + the build-time
        # scale roundtrip bit-identically — quantization is NEVER
        # recomputed at load (a recompute would be identical today, but
        # the manifest is the contract, not the coincidence).
        arrays["quant/codes"] = np.asarray(idx.quant.codes)
        arrays["quant/scale"] = np.asarray(idx.quant.scale)
        arrays["quant/norms"] = np.asarray(idx.quant.norms)
    npz_path, man_path = _snapshot_paths(snap_dir, tag)
    ckpt_lib.atomic_write_npz(npz_path, arrays)
    manifest = {
        "format": SNAPSHOT_FORMAT,
        "tag": tag,
        "metric": idx.metric,
        "entry": int(idx.entry),
        "params": {"L": int(idx.params.L), "M": int(idx.params.M),
                   "alpha": float(idx.params.alpha)},
        "num_shards": idx.num_shards,
        "sharded": idx.shards is not None,
        "provenance": idx.provenance,
        # SQ8 scheme descriptor (DESIGN.md §16): symmetric per-dimension
        # scale, zero_point identically 0 — recorded so a reader can
        # decode codes without importing this codebase.
        "quantize": idx.quantize,
        "quantization": (None if idx.quantize == "none" else
                         {"scheme": "sq8-symmetric-per-dim",
                          "zero_point": 0}),
        "arrays": sorted(arrays),
    }
    ckpt_lib.atomic_write_json(man_path, manifest)
    return man_path


def load_index(snap_dir: str, tag: str = "index",
               mesh=None) -> retrieval_lib.RetrievalIndex:
    """Restore a snapshot; sharded arrays go back onto the shard mesh.

    Refuses (FileNotFoundError) when the manifest is missing — including
    the torn-writer case where only the npz exists — and rejects unknown
    format versions.  Sharded indexes are re-placed with
    ``graph.place_sharded`` (default mesh: ``search_mesh(S)``), giving
    the restored index the same resident layout as a freshly partitioned
    one, so searches reuse the same cached programs and reproduce
    bit-identical results (pinned by tests/test_resilience.py).
    """
    npz_path, man_path = _snapshot_paths(snap_dir, tag)
    if not os.path.exists(man_path):
        hint = (" (an orphaned .snapshot.npz exists — a writer died "
                "mid-snapshot; the archive without its manifest is "
                "unverifiable and is ignored)" if os.path.exists(npz_path)
                else "")
        raise FileNotFoundError(
            f"no snapshot manifest {man_path}{hint}")
    with open(man_path) as f:
        manifest = json.load(f)
    fmt = manifest.get("format")
    if fmt != SNAPSHOT_FORMAT:
        raise ValueError(
            f"snapshot format {fmt!r} != supported {SNAPSHOT_FORMAT} "
            f"({man_path})")
    with np.load(npz_path) as z:
        arrays = {k: z[k] for k in z.files}
    missing = sorted(set(manifest["arrays"]) - set(arrays))
    if missing:
        raise ValueError(
            f"snapshot {npz_path} is missing arrays {missing} the "
            f"manifest promises — refusing a partial restore")
    params = vamana_lib.VamanaParams(**manifest["params"])
    shards = None
    if manifest["sharded"]:
        sg = graph_lib.ShardedGraph(
            ids=arrays["shards/ids"],
            data=arrays["shards/data"],
            global_ids=arrays["shards/global_ids"],
            entries=arrays["shards/entries"],
            counts=arrays["shards/counts"],
            centroids=arrays.get("shards/centroids"),
            flat_ids=arrays.get("shards/flat_ids"),
            qcodes=arrays.get("shards/qcodes"),
            qscale=arrays.get("shards/qscale"),
            qnorms=arrays.get("shards/qnorms"))
        shards = graph_lib.place_sharded(sg, mesh=mesh)
    quant = None
    if "quant/codes" in arrays:
        # Restored, never recomputed: the stored scale/codes ARE the
        # quantization state (DESIGN.md §16) and roundtrip bit-identically.
        quant = metric_lib.QuantizedData(
            codes=jax.numpy.asarray(arrays["quant/codes"]),
            scale=jax.numpy.asarray(arrays["quant/scale"]),
            norms=jax.numpy.asarray(arrays["quant/norms"]))
    return retrieval_lib.RetrievalIndex(
        graph_ids=(None if "graph_ids" not in arrays
                   else jax.numpy.asarray(arrays["graph_ids"])),
        keys=jax.numpy.asarray(arrays["keys"]),
        values=jax.numpy.asarray(arrays["values"]),
        search_keys=(None if "search_keys" not in arrays
                     else jax.numpy.asarray(arrays["search_keys"])),
        entry=int(manifest["entry"]),
        params=params,
        metric=manifest["metric"],
        shards=shards,
        provenance=manifest.get("provenance"),
        quantize=manifest.get("quantize", "none"),
        quant=quant)


# ---------------------------------------------------------------------------
# The composed degraded-mode searcher.
# ---------------------------------------------------------------------------

class ResilientSearcher:
    """Degraded-mode front door for retrieval search (DESIGN.md §14).

    Wraps ``retrieval.retrieval_attention_batched`` with, per call:

      1. fire the ``FaultPlan`` faults due at this call index (chaos
         harness — a production deployment passes ``plan=None`` and mutates
         ``health`` from its real failure detector instead),
      2. stall by the worst live injected delay (a slow shard bounds the
         scatter-gather merge),
      3. search with the governor's current knob rung and the health
         mask, under bounded retry-with-backoff,
      4. feed the observed wall latency back to the governor.

    ``swap_index`` hot-swaps a restored or rebuilt index between calls —
    the engine keeps its slots, cache, and governor state; only the index
    object is replaced (crash recovery and background reindex both land
    here).  Single-threaded by design, like ``ServeEngine``'s tick loop.
    """

    def __init__(self, index: retrieval_lib.RetrievalIndex, knobs, *,
                 health: ShardHealth | None = None,
                 plan: FaultPlan | None = None,
                 retries: int = 2, backoff_s: float = 0.05,
                 clock=time.perf_counter, sleep=time.sleep,
                 **governor_kwargs):
        self.index = index
        self.health = health or ShardHealth.fresh(index.num_shards)
        if self.health.num_shards != index.num_shards:
            raise ValueError(
                f"health tracks {self.health.num_shards} shards but the "
                f"index has {index.num_shards}")
        self.plan = plan
        self._governor_kwargs = dict(governor_kwargs)
        self.governor = LatencyGovernor(knobs, **governor_kwargs)
        self.retries = retries
        self.backoff_s = backoff_s
        self.clock = clock
        self.sleep = sleep
        self.calls = 0

    @property
    def knobs(self):
        """The knob rung the next search will run with."""
        return self.governor.knobs

    def swap_index(self, new_index) -> None:
        """Hot-swap the served index (snapshot restore / background
        reindex / streaming compaction).  Health resets to all-alive for
        the new index's shard count, and the governor is REBUILT from its
        base knobs: its EWMA measured the *old* index's cost profile and
        its rung encodes degradations chosen against it, so carrying
        either over would serve the new index with stale degraded knobs
        (or judge it by a dead index's latencies).  If the shard count
        changed, the base knobs' ``num_shards``/``routed_shards`` are
        re-validated against the new index before the ladder is rebuilt
        (regression-pinned in tests/test_resilience.py)."""
        base = self.governor.base
        s = new_index.num_shards
        if getattr(base, "num_shards", s) != s:
            base = dataclasses.replace(
                base, num_shards=s,
                routed_shards=(None if base.routed_shards is None
                               else max(1, min(base.routed_shards, s))))
        self.health = ShardHealth.fresh(s)
        self.governor = LatencyGovernor(base, **self._governor_kwargs)
        self.index = new_index

    def search(self, q, **overrides):
        """One resilient search; returns (attention out, SearchResult)."""
        if self.plan is not None:
            self.index = self.plan.apply(self.calls, self.health, self.index)
        self.calls += 1
        stall = self.health.live_delay()
        if stall > 0.0:
            self.sleep(stall)
        knobs = self.governor.knobs
        kwargs = dict(knobs.batched_kwargs(),
                      shard_mask=self.health.mask(), **overrides)
        # Duck-dispatch: an index that brings its own batched-attention
        # entry point (streaming.MutableIndex — it must fold its delta
        # layer and tombstones into every search, DESIGN.md §15) is called
        # directly; a plain RetrievalIndex goes through the module-level
        # retrieval_attention_batched as before.
        fn = getattr(self.index, "attention_batched", None)
        args = (q,) if fn is not None else (self.index, q)
        fn = fn or retrieval_lib.retrieval_attention_batched
        t0 = self.clock()
        out, res = search_with_retry(
            fn, *args,
            retries=self.retries, backoff_s=self.backoff_s,
            sleep=self.sleep, **kwargs)
        jax.block_until_ready(res.pool_ids)
        self.governor.observe(self.clock() - t0 + stall)
        return out, res
