"""Retrieval attention: the paper's technique serving the LM stack.

Long-context decode attends over an enormous KV cache; RetrievalAttention
(paper ref [8]) replaces the exhaustive pass with k-ANNS over cached keys
using a proximity graph.  This module builds a PG over a layer's keys and
answers decode-time attention by searching top-k keys, attending only to
those — and the PG's construction parameters are exactly what FastPGT tunes
(examples/serve_retrieval.py runs the tuner over this index).

Attention ranks keys by raw inner product q.k, so the index is built
natively under the "ip" metric (core/metric.py): argmin (1 - q.k) is exactly
argmax q.k — no normalization, no MIPS-to-L2 reduction, and ranking is exact
rather than the angle-only approximation the old normalize-and-L2 hack gave.
``metric="cosine"`` remains available (keys unit-normalized ONCE at build
and stored on the index; queries normalized per call — never the full key
matrix again), as does plain "l2".

Serving memory model (DESIGN.md §9): decode-time searches default to
``visited_impl="hash"`` — per-query visit state is an O(ef·M·hops)
open-addressing hash set instead of the dense O(n) bitmap, so search memory
is independent of context length and the path scales to million-key caches.
Serving also defaults to width-``DEFAULT_EXPAND_WIDTH`` multi-expansion
(DESIGN.md §10): each search hop expands the W closest unexpanded frontier
nodes at once, cutting the sequential hop count ~W× and amortizing the
fixed per-hop costs (pool merge, hash probing, kernel dispatch) that
dominate decode-time latency.  Builders keep the dense, W = 1 defaults
(§2.1 bit-identity of build outputs).

``retrieval_attention`` answers one decode batch; for heavy traffic,
``retrieval_attention_batched`` blocks large/ragged query batches into
static bucketed shapes (graph.bucket) so XLA compiles one search per block
shape and reuses it across requests.

Corpora beyond one device's memory shard (DESIGN.md §11):
``build_index(..., num_shards=S)`` chunk-partitions the keys and builds an
independent Vamana subindex per shard; searches then scatter-gather over a
``"shard"`` mesh axis (``search.sharded_knn_search``) and merge per-shard
pools with global ids restored.  ``num_shards=1`` (default) is bit-identical
to the unsharded path.  Scope note: sharding covers the *search-side*
corpus — prepared keys + graph live once, split across the mesh (no
replicated ``search_keys`` copy is kept) — while the attention gather
(``_attend``) still reads replicated ``keys``/``values``; sharding that
gather is the multi-host follow-up.

Scope: per-(layer, head) indexes over a frozen prefill cache (the common
RAG/long-doc serving pattern); incremental insertion reuses the same
builders batch-wise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import graph as graph_lib
from repro.core import metric as metric_lib
from repro.core import search as search_lib
from repro.core import vamana as vamana_lib

# Serving-side multi-expansion width (DESIGN.md §10): searches answer with
# the same pools a W = 1 search would find at equal recall targets, in ~W×
# fewer sequential hops; builders deliberately do NOT share this default.
DEFAULT_EXPAND_WIDTH = 4


@dataclasses.dataclass
class RetrievalIndex:
    graph_ids: jax.Array | None  # (n_ctx, M_max) over one head's keys
                                 # (None when the index is sharded)
    keys: jax.Array            # (n_ctx, dh) raw keys (attention logits)
    values: jax.Array          # (n_ctx, dh)
    search_keys: jax.Array | None  # (n_ctx, dh) metric-prepared ONCE at
                                   # build (None when sharded: the prepared
                                   # keys live split across shards.data —
                                   # keeping a replicated copy too would
                                   # double per-host memory)
    entry: int                 # global entry id (unsharded search path;
                               # sharded searches use shards.entries)
    params: vamana_lib.VamanaParams
    metric: str                # public metric name ("ip" | "cosine" | "l2")
    shards: graph_lib.ShardedGraph | None = None   # mesh-partitioned index
    provenance: dict | None = None   # build knobs (build_impl/assign/seed/
                                     # batch_size) — recorded by build_index
                                     # so snapshot manifests can say how the
                                     # index was built (DESIGN.md §14)
    quantize: str = "none"           # corpus representation searches use by
                                     # default (DESIGN.md §16): "sq8" beam-
                                     # searches int8 codes + fp32 re-rank
    quant: metric_lib.QuantizedData | None = None  # unsharded SQ8 codes,
                                     # computed ONCE at build (sharded
                                     # indexes carry theirs on shards.q*)

    @property
    def kernel(self) -> str:
        """Kernel form searches run under (search_keys are pre-prepared)."""
        return metric_lib.resolve(self.metric).kernel

    @property
    def num_shards(self) -> int:
        return 1 if self.shards is None else self.shards.num_shards


def build_index(keys: jax.Array, values: jax.Array,
                params: vamana_lib.VamanaParams, *, metric: str = "ip",
                seed: int = 0, batch_size: int = 256,
                num_shards: int = 1, assign: str = "chunked",
                build_impl: str = "per_batch",
                quantize: str = "none") -> RetrievalIndex:
    """Index one head's keys under ``metric`` (default: native ip/MIPS).

    Any metric preparation (unit-normalization for cosine) happens exactly
    once here; ``search_keys`` stores the prepared matrix so query-time
    never touches the full cache again.

    ``num_shards > 1`` partitions the keys (placement policy ``assign``:
    "chunked" | "random" | "kmeans", graph.ASSIGNMENTS — kmeans clusters
    the keys so centroid routing can skip shards, DESIGN.md §13) and
    builds an independent Vamana subindex per shard (same ``params``);
    searches then run scatter-gather over a ``"shard"`` mesh axis
    (``search.sharded_knn_search``, DESIGN.md §11) so no device ever holds
    the whole corpus.  The default 1 is bit-identical to the unsharded
    path — same builder call, same ``knn_search``.

    ``build_impl="fused"`` runs the Vamana build's whole insertion pass as
    one compiled dispatch (DESIGN.md §12) — same graphs up to documented
    ppm-level FP ties, less host dispatch overhead while prefill indexes
    are constructed.

    ``quantize="sq8"`` (DESIGN.md §16) additionally stores an int8 SQ8
    view of the prepared keys (scale computed ONCE here, carried on the
    index and its snapshots) so searches beam over 4×-smaller codes and
    re-rank against fp32.  The graph build itself ALWAYS runs fp32 — the
    builders and the tuner's estimation stay bit-identical (§2.1) and the
    paper-exact #dist accounting holds — so "sq8" changes search-time
    representation only.
    """
    met = metric_lib.resolve(metric)
    if quantize not in metric_lib.QUANTIZE_MODES:
        raise ValueError(
            f"quantize {quantize!r} not in {metric_lib.QUANTIZE_MODES}")
    search_keys = met.prepare(keys)
    prov = {"build_impl": build_impl, "assign": assign, "seed": seed,
            "batch_size": batch_size, "num_shards": num_shards,
            "quantize": quantize}
    if num_shards == 1:
        res = vamana_lib.build_vamana(search_keys, params, seed=seed,
                                      batch_size=batch_size,
                                      metric=met.kernel,
                                      build_impl=build_impl)
        quant = (metric_lib.quantize_sq8(search_keys)
                 if quantize == "sq8" else None)
        return RetrievalIndex(graph_ids=res.g.ids[0], keys=keys,
                              values=values, search_keys=search_keys,
                              entry=res.entry, params=params,
                              metric=met.name, provenance=prov,
                              quantize=quantize, quant=quant)

    def shard_builder(local):
        res = vamana_lib.build_vamana(local, params, seed=seed,
                                      batch_size=batch_size,
                                      metric=met.kernel,
                                      build_impl=build_impl)
        return res.g.ids[0], res.entry

    shards = graph_lib.partition(search_keys, num_shards,
                                 assignment=assign, seed=seed,
                                 build_fn=shard_builder, metric=met.kernel,
                                 quantize=quantize)
    entry = int(shards.global_ids[0][int(shards.entries[0])])
    return RetrievalIndex(graph_ids=None, keys=keys, values=values,
                          search_keys=None, entry=entry,
                          params=params, metric=met.name, shards=shards,
                          provenance=prov, quantize=quantize)


def _attend(idx: RetrievalIndex, q: jax.Array, pool_ids: jax.Array,
            scale: float | None) -> jax.Array:
    """Softmax-attend queries (B, dh) over retrieved key ids (B, k)."""
    dh = q.shape[-1]
    scale = scale or 1.0 / (dh ** 0.5)
    ids = jnp.maximum(pool_ids, 0)                        # (B, k)
    valid = pool_ids >= 0
    k_sel = idx.keys[ids]                                 # (B, k, dh)
    v_sel = idx.values[ids]
    logits = jnp.einsum("bd,bkd->bk", q, k_sel) * scale
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, v_sel)


def _search_index(idx: RetrievalIndex, qs: jax.Array, top_k: int, ef: int,
                  visited_impl: str, expand_width: int,
                  row_mask: jax.Array | None = None,
                  routed_shards: int | None = None,
                  shard_mask=None,
                  tombstone_ids=None,
                  quantize: str | None = None) -> search_lib.SearchResult:
    """Route one prepared-query batch to the un- or mesh-sharded search.

    ``tombstone_ids`` (int32[T] global ids, INVALID-padded) masks deleted
    nodes out of the merged pool on either path (DESIGN.md §15); the
    streaming MutableIndex is the owner of the mask.

    ``quantize=None`` defaults to the index's own representation
    (``build_index(quantize=...)``, DESIGN.md §16); pass "none" to force
    the fp32 path of a quantized index (parity checks) or "sq8" to search
    an index that stored codes.
    """
    quantize = idx.quantize if quantize is None else quantize
    if idx.shards is not None:
        return search_lib.sharded_knn_search(
            idx.shards, qs, top_k, ef, metric=idx.kernel,
            visited_impl=visited_impl, expand_width=expand_width,
            row_mask=row_mask, routed_shards=routed_shards,
            shard_mask=shard_mask, tombstone_ids=tombstone_ids,
            quantize=quantize)
    if routed_shards not in (None, 1):
        raise ValueError(
            f"routed_shards={routed_shards} on an unsharded index: routing "
            f"selects among shards, so build the index with num_shards > 1 "
            f"(DESIGN.md §13)")
    if shard_mask is not None:
        raise ValueError(
            "shard_mask on an unsharded index: liveness masking selects "
            "among shards, so build the index with num_shards > 1 "
            "(DESIGN.md §14)")
    return search_lib.knn_search(
        idx.graph_ids, idx.search_keys, qs, top_k, ef, idx.entry,
        metric=idx.kernel, visited_impl=visited_impl,
        expand_width=expand_width, row_mask=row_mask,
        tombstone_ids=tombstone_ids, quantize=quantize,
        quant=idx.quant if quantize == "sq8" else None)


def retrieval_attention(idx: RetrievalIndex, q: jax.Array, *, top_k: int,
                        ef: int, scale: float | None = None,
                        visited_impl: str = "hash",
                        expand_width: int = DEFAULT_EXPAND_WIDTH,
                        routed_shards: int | None = None,
                        shard_mask=None,
                        quantize: str | None = None,
                        ) -> tuple[jax.Array, search_lib.SearchResult]:
    """Approximate attention for decode queries q: (B, dh).

    Searches the PG for top_k keys per query and softmax-attends over just
    those.  Returns (out (B, dh), SearchResult for instrumentation).
    Search state is O(ef)-memory hash-set based by default (DESIGN.md §9);
    pass ``visited_impl="dense"`` to get the exact-counter bitmap path.
    ``expand_width`` is the per-hop frontier width (DESIGN.md §10) —
    1 reproduces the paper's sequential schedule exactly.  On an index
    built with ``num_shards > 1`` the search scatter-gathers across the
    shard mesh (DESIGN.md §11) and returns global key ids either way;
    ``routed_shards=p`` searches only each query's top-p shards by
    centroid distance (DESIGN.md §13 — pair with
    ``build_index(assign="kmeans")`` for shards worth routing between).
    ``shard_mask`` (bool[S]) excludes dead shards from routing and merge —
    degraded-mode serving, DESIGN.md §14 (serve.resilience owns the mask).
    ``quantize`` (None = the index's own mode, DESIGN.md §16) selects the
    corpus representation the search beams over.
    """
    met = metric_lib.resolve(idx.metric)
    qs = met.prepare(q)            # per-call cost is (B, dh) — keys untouched
    res = _search_index(idx, qs, top_k, ef, visited_impl, expand_width,
                        routed_shards=routed_shards, shard_mask=shard_mask,
                        quantize=quantize)
    return _attend(idx, q, res.pool_ids, scale), res


def retrieval_attention_batched(
    idx: RetrievalIndex, q: jax.Array, *, top_k: int, ef: int,
    scale: float | None = None, block_size: int = 64,
    visited_impl: str = "hash",
    expand_width: int = DEFAULT_EXPAND_WIDTH,
    routed_shards: int | None = None,
    shard_mask=None,
    quantize: str | None = None,
) -> tuple[jax.Array, search_lib.SearchResult]:
    """Query-blocked retrieval attention for serving-sized batches.

    Splits q: (B, dh) into blocks of a static bucketed size
    (``graph.bucket``: ragged tails pad up to a multiple of 16, masked via
    ``row_mask`` so padding rows do no search work), which keeps the set of
    compiled search shapes small and reused across requests of any B.
    Returns the same (out, SearchResult) pair as ``retrieval_attention``
    with per-block pools concatenated and #dist counters summed (``hops``
    is the max over blocks; cache fields are the last block's dummies).
    """
    B, dh = q.shape
    if B == 0:
        raise ValueError("empty query batch")
    met = metric_lib.resolve(idx.metric)
    qs_all = met.prepare(q)
    bs = graph_lib.bucket(min(block_size, B), 16)
    pool_ids, pool_dist, n_fresh, n_comp, hop_cnt = [], [], [], [], []
    res = None
    for off in range(0, B, bs):
        nrows = min(bs, B - off)
        qb = jnp.zeros((bs, dh), qs_all.dtype).at[:nrows].set(
            qs_all[off:off + nrows])
        res = _search_index(idx, qb, top_k, ef, visited_impl, expand_width,
                            row_mask=jnp.arange(bs) < nrows,
                            routed_shards=routed_shards,
                            shard_mask=shard_mask, quantize=quantize)
        # accumulate device scalars — no host sync inside the dispatch loop
        pool_ids.append(res.pool_ids[:nrows])
        pool_dist.append(res.pool_dist[:nrows])
        n_fresh.append(res.n_fresh)
        n_comp.append(res.n_computed)
        hop_cnt.append(res.hops)
    ids = jnp.concatenate(pool_ids, axis=0)
    agg = search_lib.SearchResult(
        ids, jnp.concatenate(pool_dist, axis=0),
        jnp.sum(jnp.stack(n_fresh)), jnp.sum(jnp.stack(n_comp)),
        jnp.max(jnp.stack(hop_cnt)), res.cache_d, res.cache_has)
    return _attend(idx, q, ids, scale), agg


def exact_attention(keys: jax.Array, values: jax.Array, q: jax.Array,
                    scale: float | None = None) -> jax.Array:
    """Dense reference for quality checks (recall of attention mass)."""
    dh = q.shape[-1]
    scale = scale or 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bd,nd->bn", q, keys) * scale
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bn,nd->bd", w, values)
