"""Retrieval attention: the paper's technique serving the LM stack.

Long-context decode attends over an enormous KV cache; RetrievalAttention
(paper ref [8]) replaces the exhaustive pass with k-ANNS over cached keys
using a proximity graph.  This module builds a Vamana PG over a layer's
keys and answers decode-time attention by searching top-k keys, attending
only to those — and the PG's construction parameters are exactly what
FastPGT tunes (examples/serve_retrieval.py runs the tuner over this index).

Scope: per-(layer, head) indexes over a frozen prefill cache (the common
RAG/long-doc serving pattern); incremental insertion reuses the same
builders batch-wise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import search as search_lib
from repro.core import vamana as vamana_lib


@dataclasses.dataclass
class RetrievalIndex:
    graph_ids: jax.Array       # (n_ctx, M_max) over one head's keys
    keys: jax.Array            # (n_ctx, dh) — note: inner-product queries
    values: jax.Array          # (n_ctx, dh)
    entry: int
    params: vamana_lib.VamanaParams


def build_index(keys: jax.Array, values: jax.Array,
                params: vamana_lib.VamanaParams, *, seed: int = 0,
                batch_size: int = 256) -> RetrievalIndex:
    """Index one head's keys.  L2 PG over unit-normalized keys approximates
    max-inner-product ranking for decode queries (standard MIPS reduction)."""
    norm = jnp.linalg.norm(keys, axis=-1, keepdims=True)
    kn = keys / jnp.maximum(norm, 1e-6)
    res = vamana_lib.build_vamana(kn, params, seed=seed,
                                  batch_size=batch_size)
    return RetrievalIndex(graph_ids=res.g.ids[0], keys=keys, values=values,
                          entry=res.entry, params=params)


def retrieval_attention(idx: RetrievalIndex, q: jax.Array, *, top_k: int,
                        ef: int, scale: float | None = None
                        ) -> tuple[jax.Array, search_lib.SearchResult]:
    """Approximate attention for decode queries q: (B, dh).

    Searches the PG for top_k keys per query and softmax-attends over just
    those.  Returns (out (B, dh), SearchResult for instrumentation).
    """
    dh = q.shape[-1]
    scale = scale or 1.0 / (dh ** 0.5)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-6)
    res = search_lib.knn_search(idx.graph_ids, idx.keys / jnp.maximum(
        jnp.linalg.norm(idx.keys, axis=-1, keepdims=True), 1e-6),
        qn, top_k, ef, idx.entry)
    ids = jnp.maximum(res.pool_ids, 0)                    # (B, k)
    valid = res.pool_ids >= 0
    k_sel = idx.keys[ids]                                 # (B, k, dh)
    v_sel = idx.values[ids]
    logits = jnp.einsum("bd,bkd->bk", q, k_sel) * scale
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, v_sel), res


def exact_attention(keys: jax.Array, values: jax.Array, q: jax.Array,
                    scale: float | None = None) -> jax.Array:
    """Dense reference for quality checks (recall of attention mass)."""
    dh = q.shape[-1]
    scale = scale or 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bd,nd->bn", q, keys) * scale
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bn,nd->bd", w, values)
