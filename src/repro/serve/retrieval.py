"""Retrieval attention: the paper's technique serving the LM stack.

Long-context decode attends over an enormous KV cache; RetrievalAttention
(paper ref [8]) replaces the exhaustive pass with k-ANNS over cached keys
using a proximity graph.  This module builds a PG over a layer's keys and
answers decode-time attention by searching top-k keys, attending only to
those — and the PG's construction parameters are exactly what FastPGT tunes
(examples/serve_retrieval.py runs the tuner over this index).

Attention ranks keys by raw inner product q.k, so the index is built
natively under the "ip" metric (core/metric.py): argmin (1 - q.k) is exactly
argmax q.k — no normalization, no MIPS-to-L2 reduction, and ranking is exact
rather than the angle-only approximation the old normalize-and-L2 hack gave.
``metric="cosine"`` remains available (keys unit-normalized ONCE at build
and stored on the index; queries normalized per call — never the full key
matrix again), as does plain "l2".

Scope: per-(layer, head) indexes over a frozen prefill cache (the common
RAG/long-doc serving pattern); incremental insertion reuses the same
builders batch-wise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import metric as metric_lib
from repro.core import search as search_lib
from repro.core import vamana as vamana_lib


@dataclasses.dataclass
class RetrievalIndex:
    graph_ids: jax.Array       # (n_ctx, M_max) over one head's keys
    keys: jax.Array            # (n_ctx, dh) raw keys (attention logits)
    values: jax.Array          # (n_ctx, dh)
    search_keys: jax.Array     # (n_ctx, dh) metric-prepared ONCE at build
    entry: int
    params: vamana_lib.VamanaParams
    metric: str                # public metric name ("ip" | "cosine" | "l2")

    @property
    def kernel(self) -> str:
        """Kernel form searches run under (search_keys are pre-prepared)."""
        return metric_lib.resolve(self.metric).kernel


def build_index(keys: jax.Array, values: jax.Array,
                params: vamana_lib.VamanaParams, *, metric: str = "ip",
                seed: int = 0, batch_size: int = 256) -> RetrievalIndex:
    """Index one head's keys under ``metric`` (default: native ip/MIPS).

    Any metric preparation (unit-normalization for cosine) happens exactly
    once here; ``search_keys`` stores the prepared matrix so query-time
    never touches the full cache again.
    """
    met = metric_lib.resolve(metric)
    search_keys = met.prepare(keys)
    res = vamana_lib.build_vamana(search_keys, params, seed=seed,
                                  batch_size=batch_size, metric=met.kernel)
    return RetrievalIndex(graph_ids=res.g.ids[0], keys=keys, values=values,
                          search_keys=search_keys, entry=res.entry,
                          params=params, metric=met.name)


def retrieval_attention(idx: RetrievalIndex, q: jax.Array, *, top_k: int,
                        ef: int, scale: float | None = None
                        ) -> tuple[jax.Array, search_lib.SearchResult]:
    """Approximate attention for decode queries q: (B, dh).

    Searches the PG for top_k keys per query and softmax-attends over just
    those.  Returns (out (B, dh), SearchResult for instrumentation).
    """
    dh = q.shape[-1]
    scale = scale or 1.0 / (dh ** 0.5)
    met = metric_lib.resolve(idx.metric)
    qs = met.prepare(q)            # per-call cost is (B, dh) — keys untouched
    res = search_lib.knn_search(idx.graph_ids, idx.search_keys, qs,
                                top_k, ef, idx.entry, metric=met.kernel)
    ids = jnp.maximum(res.pool_ids, 0)                    # (B, k)
    valid = res.pool_ids >= 0
    k_sel = idx.keys[ids]                                 # (B, k, dh)
    v_sel = idx.values[ids]
    logits = jnp.einsum("bd,bkd->bk", q, k_sel) * scale
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, v_sel), res


def exact_attention(keys: jax.Array, values: jax.Array, q: jax.Array,
                    scale: float | None = None) -> jax.Array:
    """Dense reference for quality checks (recall of attention mass)."""
    dh = q.shape[-1]
    scale = scale or 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bd,nd->bn", q, keys) * scale
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bn,nd->bd", w, values)
